"""Distributed replay serving: an RPC front on ``RegionServer.submit``.

The single-process :class:`~repro.serving.server.RegionServer` already makes
multi-tenant replay cheap (coalescing, interning, AOT hydration); this
module is the step from "serve many tenants fast in one process" to "serve
them from a pool of worker processes" — the distributed-manager shape of
Bosch et al. (arXiv:2009.03066): **central admission, decentralized
execution**. Three pieces:

* :class:`WorkerNode` — one process, one ``RegionServer``, one RPC listener
  (:mod:`repro.serving.rpc`). It registers tenants from shipped TDG JSON
  (payloads re-linked by symbol through an importable
  ``serialize.TaskFnRegistry``), **hydrates compiled executables from
  artifact bytes shipped in-band** (``serialize.executable_from_bytes``)
  instead of re-lowering, and serves ``submit`` asynchronously so requests
  arriving over one connection still coalesce in its admission queue.

* :class:`ClusterFrontend` — the client-facing tier. Its fleet comes from
  the spawners in :mod:`repro.serving.spawner`: ``workers=N`` spawns N
  local processes via ``multiprocessing`` (spawn by default: a fresh jax
  per worker), while ``workers=["host:port", "local", ...]`` mixes
  pre-started **remote** workers (bootstrapped on their hosts with
  ``python -m repro.serving.worker``) with locally spawned ones — both
  kinds sit behind the same router, artifact shipping and death-requeue.
  Every tenant routes to a worker **sticky by structure**: the routing key
  is the TDG's ``structure_signature`` + payload symbols, so structurally
  identical tenants land on the same worker and that worker's
  ``WarmPool``/intern cache stays hot (N tenants, ONE executable, and
  cross-tenant request coalescing keeps working across the RPC boundary).

* :class:`StickyRouter` — the routing table itself: least-loaded assignment
  on first sight of a structure, sticky thereafter, re-routable around dead
  workers.

**Warm-artifact shipping.** A tenant registered with ``warm_path=`` (or
warmed via :meth:`ClusterFrontend.warmup`) has its compiled executable held
as bytes on the frontend; registration ships those bytes with the TDG so a
cold worker *hydrates* instead of re-lowering — the cross-process replay
story of ``serialize.warmup_and_save`` carried over the wire
(``benchmarks/cluster.py`` gates that this beats re-lowering on cold
start). Shipping is **platform-aware**: every artifact embeds a
device-topology fingerprint (``serialize.topology_fingerprint``) and a
worker checks it at register time, rejecting a cross-platform/cross-version
artifact loudly (``aot_topology_rejects``) and re-lowering instead of
crashing inside XLA deserialization. A worker that receives artifact bytes
it cannot hydrate for any other reason serves the tenant lazily but reports
``aot_hydrate_failures`` in its metrics — a poisoned artifact is loud,
never silently cold.

**Failure handling.** A worker death surfaces as a broken connection; the
frontend fails that worker's in-flight futures, re-routes its tenants to
siblings (re-shipping TDGs + held artifacts), and retries the dead
requests there (``requeues``/``worker_deaths`` counters). Payloads are
pure functions over explicit buffers, so a replayed request is safe to
re-execute. :meth:`ClusterFrontend.stats` aggregates every worker's
server metrics (including ``aot_hydrate_failures``) next to the frontend's
own routing/failover counters, so the cross-process view stays as
observable as the in-process one (cf. arXiv:2406.03077).

**The wire path.** Submissions do not travel one frame per request. Each
worker handle runs a dispatcher thread draining a per-worker submit queue:
every tick it packs up to ``_WIRE_BATCH`` queued submissions into ONE
``submit_batch`` frame (compact binary codec, tensor blobs optionally via
the shared-memory data plane — :mod:`repro.serving.shm`), and keeps up to
``REPRO_RPC_WINDOW`` such frames in flight per connection, so wire latency
overlaps worker compute instead of serializing with it. The worker admits
the whole frame under one queue-lock acquisition
(``RegionServer.submit_many``) — its coalescer sees the frame's worth of
requests at once, not a trickle — and a per-connection reply writer drains
*completed* requests into ``result_batch`` frames as they finish (no
head-of-line blocking on a straggler). Replies fan back out to per-request
futures by id. Control traffic (register/warmup/stats) stays on plain
JSON frames.

Env knobs: ``REPRO_CLUSTER_WORKERS`` (default worker count, used by
``ClusterFrontend(workers=None)`` and ``launch/serve.py --cluster 0``),
``REPRO_SHIP_ARTIFACTS=0`` (kill switch: never ship compiled bytes; cold
workers re-lower), ``REPRO_RPC_TOKEN`` (default handshake auth token for
frontend and workers), ``REPRO_RPC_MAX_FRAME`` (wire frame cap),
``REPRO_RPC_TRANSPORT`` / ``REPRO_RPC_WINDOW`` / ``REPRO_RPC_SHM_BYTES``
/ ``REPRO_RPC_SHM_MIN_BYTES`` (transport selection, pipelining window and
shm ring sizing — see :mod:`repro.serving.rpc`).

**Self-healing.** A supervisor thread leases every worker via heartbeat
probes (``REPRO_HEARTBEAT_SECS`` × ``REPRO_LEASE_MISSES`` of silence
declares a worker dead — proactively, not just on socket error, and
without mistaking slow for dead: probes are answered inline on the
worker's connection thread, never queued behind replay). Dead *local*
workers are respawned in place with capped exponential backoff (at most
``REPRO_RESPAWN_MAX`` attempts per slot), re-registered with their routed
tenants and re-shipped the frontend-held warm artifacts, so a replacement
serves AOT-warm from its first request. Every submission carries an
absolute deadline (``REPRO_REQUEST_DEADLINE`` seconds, propagated in the
wire frame as a relative ttl); expired work is shed at every hop, and
``WorkerDied`` failures retry on a sibling/respawned worker with jittered
backoff under a per-request budget (``REPRO_RETRY_BUDGET``). The worker's
admission queue is bounded (``REPRO_QUEUE_BOUND``) with explicit
``QueueFull`` shedding. Deterministic fault injection for all of the
above lives in :mod:`repro.serving.faults` (``REPRO_FAULT_PLAN``).
"""
from __future__ import annotations

import importlib
import itertools
import json
import os
import random
import secrets
import socket
import threading
import time
from collections import deque
from concurrent.futures import Future
from concurrent.futures import TimeoutError as _FuturesTimeout
from typing import Any, Callable, Mapping, Sequence

from ..core import serialize as _serialize
from ..core.tdg import TDG, structure_signature
from . import faults as _faults
from . import rpc
from .server import (DeadlineExceeded, QueueFull, RateLimited,
                     RegionServer)
from .spawner import (LocalSpawner, RemoteSpawner, SpawnedWorker,
                      parse_worker_spec)

# Typed serving errors that must survive the wire round trip: the worker
# str-formats them as "TypeName: detail", the frontend maps the prefix
# back through this registry (see _WorkerHandle._remote_error). All three
# are terminal — never retried as if the worker had died.
for _cls in (DeadlineExceeded, QueueFull, RateLimited):
    rpc.register_wire_error(_cls)
del _cls

_WORKERS_ENV = "REPRO_CLUSTER_WORKERS"
_SHIP_ENV = "REPRO_SHIP_ARTIFACTS"
_TOKEN_ENV = "REPRO_RPC_TOKEN"
_RESPAWN_ENV = "REPRO_RESPAWN_MAX"
_DEADLINE_ENV = "REPRO_REQUEST_DEADLINE"
_RETRY_ENV = "REPRO_RETRY_BUDGET"

#: Respawn backoff: first retry after ~_BACKOFF_BASE seconds, doubling per
#: consecutive failure, capped — a worker slot that keeps dying retries at
#: a bounded, jittered cadence instead of hammering the host.
_BACKOFF_BASE = 0.25
_BACKOFF_CAP = 5.0


def _env_float(name: str, default: float) -> float:
    raw = os.environ.get(name, "").strip()
    if not raw:
        return default
    try:
        return float(raw)
    except ValueError:
        raise ValueError(f"{name}={raw!r} is not a number") from None


def _env_int(name: str, default: int) -> int:
    raw = os.environ.get(name, "").strip()
    if not raw:
        return default
    try:
        return int(raw)
    except ValueError:
        raise ValueError(f"{name}={raw!r} is not an integer") from None


class ClusterError(RuntimeError):
    """Frontend-level failure (no live workers, registration conflict...)."""


class ClusterRemoteError(ClusterError):
    """A worker executed the request and reported an error (bad request,
    payload failure): the *request* failed, the worker is fine."""


class WorkerDied(ClusterError):
    """The connection to a worker broke: the worker is gone, the request
    may be retried on a sibling."""


def resolve_registry(spec, kwargs: Mapping[str, Any] | None = None
                     ) -> "_serialize.TaskFnRegistry":
    """Resolve a registry spec to a ``TaskFnRegistry`` (frontend & workers).

    ``spec`` is either a ``TaskFnRegistry`` already (frontend-side
    convenience; NOT shippable to a spawned worker) or an importable
    ``"module:attr"`` string where ``attr`` is a registry or a callable
    returning one (called with ``kwargs``). The string form is what makes
    payload re-linking work across processes: both sides import the same
    symbols instead of pickling closures.
    """
    if isinstance(spec, _serialize.TaskFnRegistry):
        return spec
    if not isinstance(spec, str) or ":" not in spec:
        raise ValueError(
            "registry must be a TaskFnRegistry or an importable "
            f"'module:attr' string, got {spec!r}")
    mod_name, attr = spec.split(":", 1)
    obj = getattr(importlib.import_module(mod_name), attr)
    if isinstance(obj, _serialize.TaskFnRegistry):
        if kwargs:
            raise ValueError(f"{spec!r} is a registry instance; "
                             "registry_kwargs only apply to a factory")
        return obj
    registry = obj(**dict(kwargs or {}))
    if not isinstance(registry, _serialize.TaskFnRegistry):
        raise TypeError(f"{spec!r} returned {type(registry).__name__}, "
                        "expected TaskFnRegistry")
    return registry


# ---------------------------------------------------------------------------
# Worker side
# ---------------------------------------------------------------------------

class _ReplyWriter:
    """Per-connection reply coalescer (worker side).

    Completed submit futures land here (from executor callback threads) and
    a single writer thread drains whatever has accumulated into ONE
    ``result_batch`` frame per pass — opportunistic coalescing: a burst of
    completions shares a frame, a lone straggler ships alone immediately.
    Having exactly one thread send binary frames on the connection is also
    what keeps the shm ring single-producer (see :mod:`repro.serving.shm`).
    """

    def __init__(self, conn: "rpc.RpcConnection"):
        self._conn = conn
        self._cv = threading.Condition()
        self._done: list[tuple[Any, Future]] = []
        self._closed = False
        self._thread = threading.Thread(target=self._loop,
                                        name="worker-reply-writer",
                                        daemon=True)
        self._thread.start()

    def complete(self, mid, fut: Future) -> None:
        with self._cv:
            if self._closed:
                return
            self._done.append((mid, fut))
            self._cv.notify_all()

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()

    def _loop(self) -> None:
        while True:
            with self._cv:
                while not self._done and not self._closed:
                    self._cv.wait()
                if not self._done:      # closed and drained
                    return
                batch, self._done = self._done, []
            entries = []
            for mid, fut in batch:
                exc = fut.exception()
                if exc is not None:
                    entries.append({"id": mid,
                                    "error": f"{type(exc).__name__}: {exc}"})
                else:
                    entries.append({"id": mid, "out": fut.result()})
            try:
                self._conn.send({"op": "result_batch", "entries": entries},
                                codec="binary")
            except (OSError, rpc.ProtocolError):
                return              # connection is dying; nothing to flush to


class WorkerNode:
    """One worker process: an RPC listener wrapped around a ``RegionServer``.

    ``submit`` is handled *asynchronously* — the connection reader enqueues
    into the server's admission queue and replies from a completion
    callback — so many in-flight requests from one frontend connection
    coalesce exactly as in-process callers would. Everything else
    (register/warmup/stats/ping/shutdown) is handled inline: rare, fast, or
    deliberately serializing (warmup).

    Every accepted connection must open with the RPC handshake
    (:func:`rpc.server_handshake`): protocol version pinned, ``token``
    checked when set, and the ack advertises this worker's pid/port and
    device-topology fingerprint. A connection that fails the handshake is
    dropped before it can touch the server. Shipped artifacts whose
    embedded fingerprint disagrees with this host are rejected at register
    time (counted in ``aot_topology_rejects``; the tenant re-lowers).
    """

    def __init__(self, registry: "_serialize.TaskFnRegistry",
                 host: str = "127.0.0.1", port: int = 0,
                 token: str | None = None, handshake_timeout: float = 30.0,
                 transport: str | None = None,
                 server: RegionServer | None = None, **server_kwargs):
        self.registry = registry
        self.token = token
        self.handshake_timeout = handshake_timeout
        # Arm any env-shipped chaos plan, with this process's role: a
        # spawned worker inherits REPRO_FAULT_PLAN from the frontend's
        # environment, so one export arms the whole fleet.
        _faults.init_from_env("worker")
        # The worker's OWN transport policy (its env / CLI, not the
        # frontend's): "tcp" refuses shm-setup offers, "shm"/"auto" attach
        # when the segments are reachable. Independence is deliberate — a
        # worker that knows it cannot share memory (containerized, remote)
        # pins itself to tcp and the frontend falls back per connection.
        self.transport = rpc.transport_mode(transport)
        self.server = server or RegionServer(
            name=f"worker-{os.getpid()}", **server_kwargs)
        self.listener = rpc.listener(host, port)
        self.port = self.listener.getsockname()[1]
        # Pinned buffers arrive once per *group* and are shared by every
        # tenant that references the group key, so all those tenants merge
        # the SAME decoded array objects into their requests — which is
        # exactly what lets RegionServer's coalescer recognize them as
        # shared (object identity) and broadcast instead of stack.
        self._pin_groups: dict[str, dict] = {}
        self._tenant_pin: dict[str, str] = {}
        self._stop = threading.Event()
        self._conn_threads: list[threading.Thread] = []
        # Worker-local counters beyond the server's own metrics.
        self.hydrated_inband = 0

    # ------------------------------------------------------------------ loop
    def serve_forever(self) -> None:
        """Accept frontend connections until a ``shutdown`` op arrives.

        The listener polls with a short timeout rather than blocking
        forever: ``close()``-ing a socket does not reliably wake a thread
        blocked in ``accept()``, so a purely blocking loop would strand
        the process after a shutdown op handled on a connection thread.
        """
        self.listener.settimeout(0.2)
        try:
            while not self._stop.is_set():
                try:
                    sock, _addr = self.listener.accept()
                except socket.timeout:
                    continue
                except OSError:        # listener closed by shutdown
                    break
                sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                conn = rpc.RpcConnection(sock)
                t = threading.Thread(target=self._conn_loop, args=(conn,),
                                     name="worker-conn", daemon=True)
                t.start()
                # Prune finished threads so a network-exposed worker doesn't
                # accumulate one entry per client for its whole lifetime.
                self._conn_threads = [ct for ct in self._conn_threads
                                      if ct.is_alive()]
                self._conn_threads.append(t)
        finally:
            self.server.close()

    def _conn_loop(self, conn: rpc.RpcConnection) -> None:
        try:
            # A client gets handshake_timeout (absolute, trickle-proof) to
            # say hello, and the hello frame is capped small: without
            # both, a port scanner or hostile slow client could pin this
            # thread + an attacker-sized allocation forever before the
            # token is ever checked.
            rpc.server_handshake(
                conn, token=self.token, timeout=self.handshake_timeout,
                info={"pid": os.getpid(), "port": self.port,
                      "topology": _serialize.topology_fingerprint()})
            conn.sock.settimeout(None)      # deadline left a timeout armed
        except (rpc.ProtocolError, rpc.ConnectionClosed, OSError):
            # Wrong token / protocol skew / handshake timeout / port
            # scanner: the reject frame (when sendable) already told the
            # peer why; drop the socket.
            conn.close()
            return
        writer = _ReplyWriter(conn)
        try:
            while not self._stop.is_set():
                try:
                    msg = conn.recv()
                except (rpc.ProtocolError, rpc.ConnectionClosed, OSError):
                    # ProtocolError included: once framing desyncs
                    # (oversized prefix, malformed node) nothing later on
                    # this socket can be trusted — drop the connection,
                    # keep the worker.
                    return
                try:
                    self._dispatch(conn, msg, writer)
                except Exception as exc:  # never let one bad frame kill the loop
                    self._send_error(conn, msg.get("id"), exc)
                if msg.get("op") == "shutdown":
                    return
        finally:
            # Every exit path — shutdown op included — releases the
            # connection (and any attached shm rings) and stops its reply
            # writer; the shutdown path used to leak the socket.
            writer.close()
            conn.close()

    def _send_error(self, conn: rpc.RpcConnection, mid, exc: Exception,
                    ) -> None:
        try:
            conn.send({"op": "error", "id": mid,
                       "error": f"{type(exc).__name__}: {exc}"})
        except OSError:
            pass

    def _merged_buffers(self, tenant: str, buffers: Mapping[str, Any]
                        ) -> dict:
        pin_key = self._tenant_pin.get(tenant)
        merged = dict(self._pin_groups.get(pin_key, {}))
        merged.update(buffers)
        return merged

    def _dispatch(self, conn: rpc.RpcConnection, msg: dict,
                  writer: _ReplyWriter) -> None:
        op, mid = msg["op"], msg.get("id")
        if op == rpc.HEARTBEAT_OP:
            # Lease probe: answered INLINE on this connection thread, never
            # queued behind replay work — which is exactly what lets the
            # supervisor tell slow (acks heartbeats, results late) from
            # dead (acks nothing). The lightest round-trip the wire has.
            conn.send({"op": rpc.HEARTBEAT_ACK_OP, "id": mid})
        elif op == "submit_batch":
            # The hot path: one frame, N submissions, ONE admission-queue
            # lock acquisition (submit_many) so the server's coalescer
            # sees the whole frame at once. Per-entry failures come back
            # as pre-failed futures — routed to the right caller by id,
            # never rejecting the frame's other entries. Each entry may
            # carry a relative "ttl" (seconds of deadline remaining at
            # send time — relative because monotonic clocks do not compare
            # across hosts); it converts to a worker-local absolute
            # deadline here, and already-expired entries are shed by the
            # server before they cost a replay.
            entries = msg["entries"]
            now = time.monotonic()
            items = []
            for e in entries:
                ttl = e.get("ttl")
                deadline = now + ttl if isinstance(ttl, (int, float)) \
                    and not isinstance(ttl, bool) else None
                items.append((e["tenant"],
                              self._merged_buffers(e["tenant"], e["buffers"]),
                              deadline))
            futs = self.server.submit_many(items)
            for e, fut in zip(entries, futs):
                fut.add_done_callback(
                    lambda f, _mid=e["id"]: writer.complete(_mid, f))
        elif op == "submit":
            # Single-request form (kept for probe/test paths): same reply
            # plumbing as the batch path, so ordering and coalescing of
            # replies is uniform.
            fut = self.server.submit(
                msg["tenant"],
                self._merged_buffers(msg["tenant"], msg["buffers"]))
            fut.add_done_callback(
                lambda f, _mid=mid: writer.complete(_mid, f))
        elif op == "shm-setup":
            self._handle_shm_setup(conn, msg)
        elif op == "register":
            conn.send({"op": "result", "id": mid,
                       **self._handle_register(msg)})
        elif op == "warmup":
            # Off-thread: a warmup is a full trace+compile — minutes,
            # sometimes. Handling it inline would silence this connection's
            # heartbeat acks for the duration and get a perfectly healthy
            # worker declared dead mid-compile. The connection's write lock
            # makes the cross-thread reply send safe.
            def _do_warmup(msg=msg, mid=mid):
                try:
                    reply = {"op": "result", "id": mid,
                             **self._handle_warmup(msg)}
                except Exception as exc:
                    self._send_error(conn, mid, exc)
                    return
                try:
                    conn.send(reply)
                except (OSError, rpc.ProtocolError):
                    pass        # connection died while we compiled
            threading.Thread(target=_do_warmup, name="worker-warmup",
                             daemon=True).start()
        elif op == "stats":
            conn.send({"op": "result", "id": mid, "stats": self.stats()})
        elif op == "trace":
            conn.send({"op": "result", "id": mid,
                       "trace": self.server.metrics.trace.snapshot(),
                       "summary": self.server.metrics.trace.summary()})
        elif op == "ping":
            conn.send({"op": "result", "id": mid, "pid": os.getpid(),
                       "port": self.port})
        elif op == "shutdown":
            self._stop.set()
            conn.send({"op": "result", "id": mid, "stopping": True})
            try:
                self.listener.close()
            except OSError:
                pass
        else:
            raise ValueError(f"unknown op {op!r}")

    # ------------------------------------------------------------------- ops
    def _handle_shm_setup(self, conn: rpc.RpcConnection, msg: dict) -> None:
        """Attach (or refuse) the frontend's offered shared-memory rings.

        Any failure — worker pinned to tcp, segments unreachable (different
        host, different mount namespace), bogus names/sizes — is a clean
        ``attached: False`` reply with a reason: the frontend falls back to
        TCP and counts it; the connection survives either way.
        """
        mid = msg.get("id")
        if self.transport == "tcp":
            conn.send({"op": "result", "id": mid, "attached": False,
                       "reason": "worker transport pinned to tcp"})
            return
        tx = rx = None
        try:
            from . import shm as _shm
            size = int(msg["size"])
            # The frontend's tx ring is what IT sends on → our receive
            # side; its rx ring is our send side.
            rx = _shm.ShmRing.attach(msg["tx"], size)
            tx = _shm.ShmRing.attach(msg["rx"], size)
        except Exception as exc:
            for ring in (tx, rx):
                if ring is not None:
                    ring.close()
            conn.send({"op": "result", "id": mid, "attached": False,
                       "reason": f"{type(exc).__name__}: {exc}"})
            return
        conn.attach_rings(send_ring=tx, recv_ring=rx)
        conn.send({"op": "result", "id": mid, "attached": True})

    def _handle_register(self, msg: dict) -> dict:
        name = msg["tenant"]
        tdg = _serialize.tdg_from_dict(msg["tdg"], self.registry)
        outputs = tuple(msg["outputs"]) if msg.get("outputs") else None
        already = False
        try:
            self.server.register_tenant(name, tdg, outputs=outputs,
                                        kernel_mode=msg.get("kernel_mode"),
                                        tier=msg.get("tier"),
                                        rate=msg.get("rate"))
        except ValueError as exc:
            if "already registered" not in str(exc):
                raise
            # Failover re-registration (the frontend routed this tenant
            # here before, or is re-shipping after a sibling died): the
            # tenant and its warm state are still valid — idempotent.
            already = True
        pin_key = msg.get("pin_key")
        if pin_key is not None:
            if msg.get("pinned") is not None:
                # setdefault: the first shipment's decoded objects win, so
                # later tenants referencing this group alias the same arrays.
                self._pin_groups.setdefault(pin_key, dict(msg["pinned"]))
            elif pin_key not in self._pin_groups:
                raise ValueError(
                    f"tenant {name!r} references pin group {pin_key!r} "
                    "that was never shipped to this worker")
            self._tenant_pin[name] = pin_key
        hydrated, hydrate_error = False, None
        artifact = msg.get("artifact")
        if artifact is not None:
            try:
                # Match against THIS worker's replay mesh, not the ambient
                # env: an artifact compiled batch-sharded over 8 devices
                # must be rejected (TopologyMismatch) on a worker whose
                # server replays single-device, and vice versa.
                aot = _serialize.executable_from_bytes(
                    artifact, mesh=self.server.mesh_fp)
                self.server.install_aot(name, aot, hydrated=True)
                self.hydrated_inband += 1
                hydrated = True
            except _serialize.TopologyMismatch as exc:
                # The frontend shipped a binary compiled for different
                # hardware or jax version — caught by the fingerprint
                # check BEFORE XLA deserialization could crash the worker.
                # Reject loudly, serve by re-lowering.
                self.server.metrics.on_aot_topology_reject()
                hydrate_error = f"{type(exc).__name__}: {exc}"
            except Exception as exc:
                # Poisoned/unusable artifact: serve lazily, but LOUDLY —
                # the metric is what keeps "fell back to re-lowering"
                # from masquerading as warm in aggregated stats.
                self.server.metrics.on_aot_hydrate_failure()
                hydrate_error = f"{type(exc).__name__}: {exc}"
        return {"registered": True, "already": already,
                "hydrated": hydrated, "hydrate_error": hydrate_error}

    def _handle_warmup(self, msg: dict) -> dict:
        report = self.server.warmup(msg["tenant"], msg["buffers"])
        artifact = None
        if _serialize.executable_serialization_available():
            tenant = self.server.tenant(msg["tenant"])
            entry = self.server.pool.peek(tenant.aot_key)
            if entry is not None:
                artifact = _serialize.executable_to_bytes(entry.fn)
        return {"report": report, "artifact": artifact}

    def stats(self) -> dict:
        s = self.server.stats()
        s["worker"] = {"pid": os.getpid(), "port": self.port,
                       "hydrated_inband": self.hydrated_inband,
                       "topology": _serialize.topology_fingerprint(),
                       "transport": self.transport,
                       "pin_groups": len(self._pin_groups),
                       "pinned_tenants": sorted(self._tenant_pin)}
        return s


# ---------------------------------------------------------------------------
# Routing
# ---------------------------------------------------------------------------

class StickyRouter:
    """Structure-sticky, least-loaded tenant→worker routing table.

    The key insight (and the whole point of stickiness): a worker's
    ``WarmPool`` and intern cache are keyed by *structure*, so the cheapest
    worker for a request is whichever one already compiled that structure.
    First sight of a routing key picks the live worker with the fewest
    structures assigned; every later tenant with the same key follows it.
    ``reroute`` moves a key off a dead worker (and remembers the move).
    """

    def __init__(self, n_workers: int):
        self.n_workers = n_workers
        self._table: dict[Any, int] = {}
        self._loads = [0] * n_workers
        self._lock = threading.Lock()

    def route(self, key: Any, alive: frozenset[int] | set[int]) -> int:
        if not alive:
            raise ClusterError("no live workers to route to")
        with self._lock:
            w = self._table.get(key)
            if w is not None and w in alive:
                return w
            w = min(alive, key=lambda i: (self._loads[i], i))
            if self._table.get(key) is not None:
                self._loads[self._table[key]] -= 1
            self._table[key] = w
            self._loads[w] += 1
            return w

    def reroute(self, key: Any, alive: set[int], exclude: set[int]) -> int:
        candidates = set(alive) - set(exclude)
        if not candidates:
            raise ClusterError(
                f"no live workers left to requeue onto (alive={sorted(alive)},"
                f" excluded={sorted(exclude)})")
        with self._lock:
            old = self._table.get(key)
            w = min(candidates, key=lambda i: (self._loads[i], i))
            if old is not None:
                self._loads[old] -= 1
            self._table[key] = w
            self._loads[w] += 1
            return w

    def assignment(self) -> dict:
        with self._lock:
            return dict(self._table)


# ---------------------------------------------------------------------------
# Frontend side
# ---------------------------------------------------------------------------

class _TenantRecord:
    __slots__ = ("name", "tdg_dict", "outputs", "kernel_mode", "route_key",
                 "worker", "artifact", "pin_key", "requests", "tier", "rate")

    def __init__(self, name, tdg_dict, outputs, kernel_mode, route_key,
                 tier=None, rate=None):
        self.name = name
        self.tdg_dict = tdg_dict
        self.outputs = outputs
        self.kernel_mode = kernel_mode
        self.route_key = route_key
        self.worker: int | None = None
        self.artifact: bytes | None = None
        self.pin_key: str | None = None
        self.requests = 0
        # QoS config crosses the wire with every (re-)registration, so a
        # respawned or failover worker applies the same tier/rate policy.
        self.tier: int | None = tier
        self.rate: float | None = rate


#: Max submissions packed into one ``submit_batch`` frame. Large enough
#: that a worker's whole admission-queue wave usually arrives as one frame;
#: small enough that a frame never approaches the frame cap with typical
#: tensor payloads.
_WIRE_BATCH = 64


class _WorkerHandle:
    """Frontend-side view of one worker: dispatcher, window, reply demux.

    Submissions go through a per-worker queue drained by a dispatcher
    thread that packs up to :data:`_WIRE_BATCH` of them into one
    ``submit_batch`` frame, keeping at most ``window`` frames in flight on
    the connection (pipelining: the wire round-trip overlaps worker
    compute, and backpressure from a slow worker is a bounded window, not
    an unbounded queue of unacked frames). The batching is *self-clocking*:
    while the window is full the queue grows, so the next frame packs more
    — load adapts frame occupancy with zero tuning.

    Control requests (register/warmup/stats/ping/shutdown) bypass the
    queue: they are rare, ordered, and JSON-coded. ``process`` is the local
    ``multiprocessing.Process`` or ``None`` for a remote worker attached by
    address — the shutdown path branches on it (reap vs. best-effort RPC +
    connection close).
    """

    def __init__(self, idx: int, spawned: SpawnedWorker,
                 ids: "itertools.count", on_death: Callable[[int], None],
                 window: int | None = None):
        self.idx = idx
        self.spawned = spawned          # kept whole for respawn()
        self.kind = spawned.kind
        self.address = spawned.address
        self.info = spawned.info
        self.process = spawned.process
        self.conn = spawned.conn
        self.transport = spawned.transport
        self.shm_fallback = spawned.shm_fallback
        self.alive = True
        self._ids = ids
        self._on_death = on_death
        self._window = rpc.window_size(window)
        self._lock = threading.Lock()
        self._pending: dict[int, Future] = {}
        # mid -> absolute monotonic deadline, for the supervisor's sweep
        # (fails pending futures whose reply never arrived in time — the
        # backstop that turns a dropped result frame into a typed error
        # instead of a hang).
        self._deadlines: dict[int, float] = {}
        # mid -> shared [outstanding_count] cell of its frame: the window
        # slot frees when every entry of the frame has been answered.
        self._frame_of: dict[int, list] = {}
        self._submit_q: deque[tuple[int, str, dict, float | None]] = deque()
        self._q_cv = threading.Condition()
        self._inflight_frames = 0
        self.frames_sent = 0
        self.entries_sent = 0
        self.timeouts = 0
        # Lease state (supervisor-owned: one thread calls heartbeat_tick).
        self.heartbeat_misses = 0           # consecutive
        self.heartbeat_misses_total = 0
        self._hb_fut: Future | None = None
        self._reader = threading.Thread(target=self._read_loop,
                                        name=f"cluster-reader-{idx}",
                                        daemon=True)
        self._reader.start()
        self._writer = threading.Thread(target=self._write_loop,
                                        name=f"cluster-dispatch-{idx}",
                                        daemon=True)
        self._writer.start()

    # --------------------------------------------------------------- submits
    def submit_async(self, tenant: str, buffers: dict,
                     deadline: float | None = None) -> Future:
        """Queue one submission for the dispatcher; resolves to the reply
        entry (``{"id": ..., "out": ...}``). O(1), lock scope is a dict
        put + a queue append — the frontend's submit hot path never waits
        on the wire. ``deadline`` is an absolute ``time.monotonic()``
        instant; it rides to the worker as a relative ttl and backs the
        supervisor's no-reply sweep here."""
        fut: Future = Future()
        mid = next(self._ids)
        with self._lock:
            if not self.alive:
                raise WorkerDied(f"worker {self.idx} is dead")
            self._pending[mid] = fut
            if deadline is not None:
                self._deadlines[mid] = deadline
        with self._q_cv:
            self._submit_q.append((mid, tenant, buffers, deadline))
            self._q_cv.notify_all()
        return fut

    def _write_loop(self) -> None:
        """Dispatcher: pack queued submissions into batch frames, bounded
        by the pipelining window."""
        while True:
            with self._q_cv:
                while self.alive and (
                        not self._submit_q
                        or self._inflight_frames >= self._window):
                    self._q_cv.wait()
                if not self.alive:
                    return
                entries = []
                while self._submit_q and len(entries) < _WIRE_BATCH:
                    entries.append(self._submit_q.popleft())
            # Drop entries whose future already finished (timed out,
            # cancelled, failed by _mark_dead) or whose deadline has
            # already passed: sending them would waste worker compute on
            # an answer nobody can receive.
            live = []
            expired: list[Future] = []
            now = time.monotonic()
            with self._lock:
                for mid, tenant, buffers, deadline in entries:
                    fut = self._pending.get(mid)
                    if fut is None or fut.done():
                        self._pending.pop(mid, None)
                        self._deadlines.pop(mid, None)
                        continue
                    if deadline is not None and deadline <= now:
                        self._pending.pop(mid, None)
                        self._deadlines.pop(mid, None)
                        expired.append(fut)
                        continue
                    live.append((mid, tenant, buffers, deadline))
                if live:
                    cell = [len(live)]
                    for mid, _, _, _ in live:
                        self._frame_of[mid] = cell
            for fut in expired:
                fut.set_exception(DeadlineExceeded(
                    f"worker {self.idx}: deadline passed while queued at "
                    "the frontend"))
            if not live:
                continue
            with self._q_cv:
                self._inflight_frames += 1
            # The ttl is recomputed at PACK time (not submit time), so
            # frontend queue wait is charged against the budget; relative
            # seconds because monotonic clocks do not compare across hosts.
            frame = {"op": "submit_batch",
                     "entries": [
                         {"id": mid, "tenant": t, "buffers": b,
                          **({"ttl": d - now} if d is not None else {})}
                         for mid, t, b, d in live]}
            try:
                self.conn.send(frame, codec="binary")
            except (OSError, rpc.ProtocolError):
                self._mark_dead()
                return
            with self._lock:
                self.frames_sent += 1
                self.entries_sent += len(live)

    # -------------------------------------------------------------- control
    def request_async(self, msg: dict) -> Future:
        fut: Future = Future()
        mid = next(self._ids)
        fut._rpc_mid = mid          # lets request() disown it on timeout
        with self._lock:
            if not self.alive:
                raise WorkerDied(f"worker {self.idx} is dead")
            self._pending[mid] = fut
        try:
            self.conn.send({**msg, "id": mid})
        except OSError as exc:
            with self._lock:
                self._pending.pop(mid, None)
            self._mark_dead()
            raise WorkerDied(f"worker {self.idx}: send failed "
                             f"({exc})") from exc
        return fut

    def request(self, msg: dict, timeout: float | None = 120.0) -> dict:
        fut = self.request_async(msg)
        try:
            return fut.result(timeout=timeout)
        except _FuturesTimeout:
            # The bug this fixes: timing out used to leave the pending
            # entry (and its Future) in the demux table forever — a stuck
            # worker silently accumulated state. Disown the id so a late
            # reply is dropped by the reader, fail the future, and COUNT
            # it: a timeout is a worker-health signal, not ambient noise.
            with self._lock:
                still = self._pending.pop(fut._rpc_mid, None)
            if still is None:
                # The reply raced the timeout and the reader already
                # resolved the future — take the result, it's here.
                return fut.result(timeout=0)
            with self._lock:
                self.timeouts += 1
            err = ClusterError(
                f"worker {self.idx}: no reply to {msg.get('op')!r} "
                f"within {timeout}s")
            still.set_exception(err)
            raise err from None

    # ---------------------------------------------------------------- reader
    def _read_loop(self) -> None:
        while True:
            try:
                msg = self.conn.recv()
            except (rpc.ProtocolError, rpc.ConnectionClosed, OSError):
                # ProtocolError too: a desynced/oversized frame means this
                # connection is unusable — fall through to _mark_dead() so
                # pending futures fail fast and the router stops using it,
                # instead of the reader dying with futures hung.
                break
            if not isinstance(msg, dict):
                continue
            if msg.get("op") == "result_batch":
                for entry in msg.get("entries", ()):
                    self._complete(entry.get("id"), entry)
            else:
                self._complete(msg.get("id"), msg)
        self._mark_dead()

    def _complete(self, mid, msg: dict) -> None:
        """Resolve one reply entry; release its frame's window slot when
        the frame is fully answered."""
        with self._lock:
            fut = self._pending.pop(mid, None)
            self._deadlines.pop(mid, None)
            # Each mid is popped from _frame_of exactly once, under this
            # lock — so the cell decrement is single-shot per mid even
            # though the reader AND the supervisor's deadline sweep can
            # both retire entries.
            cell = self._frame_of.pop(mid, None)
            freed = False
            if cell is not None:
                cell[0] -= 1
                freed = cell[0] == 0
        if freed:
            with self._q_cv:
                self._inflight_frames -= 1
                self._q_cv.notify_all()
        if fut is None:
            return                  # reply to an already-abandoned request
        if msg.get("op") == "error" or (msg.get("op") is None
                                        and "error" in msg):
            fut.set_exception(self._remote_error(msg.get("error")))
        else:
            fut.set_result(msg)

    def _remote_error(self, detail) -> Exception:
        """Map a worker error string back to a typed exception.

        Worker-side errors cross the wire as ``"TypeName: detail"``;
        deadline and shedding failures must come back as their own types
        (``DeadlineExceeded`` is terminal, ``QueueFull`` means back off,
        ``RateLimited`` means slow this tenant down — none should be
        retried as if the worker had died). The name→class mapping lives
        in :func:`rpc.register_wire_error`'s registry."""
        if isinstance(detail, str):
            cls = rpc.wire_error_class(detail)
            if cls is not None:
                return cls(f"worker {self.idx}: {detail}")
        return ClusterRemoteError(f"worker {self.idx}: {detail}")

    # ------------------------------------------------------------ liveness
    def expire_deadlines(self, now: float) -> int:
        """Fail pending futures whose deadline passed with no reply.

        The supervisor calls this every tick. It is what turns a reply
        that will never arrive (dropped result frame, wedged worker) into
        a clean ``DeadlineExceeded`` instead of a caller hang — and it
        releases the affected frames' window slots so the dispatcher is
        not left jammed behind entries nobody is waiting for."""
        expired: list[Future] = []
        freed = 0
        with self._lock:
            if not self.alive:
                return 0
            for mid in [m for m, d in self._deadlines.items() if d <= now]:
                fut = self._pending.pop(mid, None)
                del self._deadlines[mid]
                cell = self._frame_of.pop(mid, None)
                if cell is not None:
                    cell[0] -= 1
                    if cell[0] == 0:
                        freed += 1
                if fut is not None and not fut.done():
                    expired.append(fut)
        if freed:
            with self._q_cv:
                self._inflight_frames -= freed
                self._q_cv.notify_all()
        for fut in expired:
            fut.set_exception(DeadlineExceeded(
                f"worker {self.idx}: no reply before the request deadline"))
        return len(expired)

    def heartbeat_tick(self, miss_budget: int) -> bool:
        """One lease tick: account the previous probe, launch the next.

        Returns ``True`` when the lease is exhausted — ``miss_budget``
        consecutive probes unanswered — and the caller should declare this
        worker dead. Unanswered probes are *disowned* (popped from the
        demux table) so a wedged worker cannot accumulate pending state;
        a probe answered within the tick resets the miss streak, which is
        what keeps a merely slow worker leased."""
        prev = self._hb_fut
        if prev is not None:
            if prev.done() and prev.exception() is None:
                self.heartbeat_misses = 0
            else:
                self.heartbeat_misses += 1
                self.heartbeat_misses_total += 1
                mid = getattr(prev, "_rpc_mid", None)
                if not prev.done() and mid is not None:
                    with self._lock:
                        self._pending.pop(mid, None)
                if self.heartbeat_misses >= miss_budget:
                    self._hb_fut = None
                    return True
        try:
            self._hb_fut = self.request_async(rpc.heartbeat_frame(0))
        except (WorkerDied, OSError):
            return True         # the socket already told us
        return False

    # -------------------------------------------------------------- teardown
    def _mark_dead(self) -> None:
        with self._lock:
            if not self.alive:
                return
            self.alive = False
            pending = list(self._pending.values())
            self._pending.clear()
            self._deadlines.clear()
            self._frame_of.clear()
        with self._q_cv:
            self._submit_q.clear()
            self._inflight_frames = 0
            self._q_cv.notify_all()     # dispatcher wakes, sees dead, exits
        # Close the connection NOW, not lazily at frontend teardown: this
        # is what unlinks the shm ring segments (a worker killed mid-frame
        # can never ack, so the segments would otherwise leak until the
        # frontend exits) and what wakes a dispatcher thread blocked in
        # ring alloc() waiting on credit the dead worker will never send —
        # the stranded-on-ring-credit half of the death bug.
        self.conn.close()
        for fut in pending:
            if not fut.done():
                fut.set_exception(WorkerDied(
                    f"worker {self.idx} died with the request in flight"))
        self._on_death(self.idx)

    def dispatch_stats(self) -> dict:
        """Dispatcher-side wire stats: framing occupancy and window state."""
        with self._q_cv:
            queued = len(self._submit_q)
            inflight = self._inflight_frames
        with self._lock:
            frames, entries = self.frames_sent, self.entries_sent
            timeouts = self.timeouts
        return {"frames_sent": frames, "entries_sent": entries,
                "entries_per_frame": (round(entries / frames, 3)
                                      if frames else 0.0),
                "inflight_frames": inflight, "queued_entries": queued,
                "window": self._window, "timeouts": timeouts}

    def close(self) -> None:
        """Orderly teardown that can never hang on (or silently drop) an
        inflight pipelined window.

        The race this closes: the dispatcher thread may be mid-``send``
        (possibly blocked on shm ring credit) while ``close()`` tears the
        socket down — and any future still queued or pending would
        otherwise just never resolve. Sequence: go not-alive and *disown*
        every queued/pending entry under the locks, wake the dispatcher,
        then close the connection (which unblocks a ring-credit wait), and
        only then fail the captured futures with a typed error.
        """
        with self._lock:
            self.alive = False
            pending = list(self._pending.values())
            self._pending.clear()
            self._deadlines.clear()
            self._frame_of.clear()
        with self._q_cv:
            self._submit_q.clear()      # dispatcher has nothing left to pack
            self._inflight_frames = 0
            self._q_cv.notify_all()     # release the dispatcher thread
        # Give a dispatcher that is between "popped entries" and "send" a
        # beat to hit the dead connection on its own...
        self._writer.join(timeout=0.5)
        # ...then close the connection: wakes a send blocked on ring
        # credit (ShmRing.close notifies allocators) and stops the reader.
        self.conn.close()
        self._writer.join(timeout=5.0)
        self._reader.join(timeout=5.0)
        for fut in pending:
            if not fut.done():
                fut.set_exception(ClusterError(
                    f"worker {self.idx}: frontend closed with the request "
                    "in flight"))


class ClusterFrontend:
    """Central admission over a fleet of ``WorkerNode`` processes/hosts.

    Exposes the same surface as :class:`RegionServer` — ``register_tenant``
    / ``submit`` / ``serve`` / ``warmup`` / ``stats`` — but routes over RPC
    with structure-sticky placement, warm-artifact shipping and
    death-requeue. Single-process semantics are untouched: each worker IS a
    ``RegionServer``; the frontend only decides *which one* sees a request.

    Parameters
    ----------
    workers:
        The fleet. An ``int`` spawns that many local worker processes
        (default count: ``REPRO_CLUSTER_WORKERS`` or 2). A sequence of
        specs mixes kinds: ``"host:port"`` attaches to a pre-started
        remote worker (``python -m repro.serving.worker`` on that host),
        the literal ``"local"`` spawns one here — e.g.
        ``workers=["10.0.0.5:7077", "local"]``.
    registry:
        The payload symbol table (see :func:`resolve_registry`). Must be an
        importable ``"module:attr"`` string whenever the fleet includes a
        locally *spawned* worker (the spec is what crosses the process
        boundary); an all-remote fleet may pass a live ``TaskFnRegistry``,
        since remote workers were bootstrapped with their own
        ``--registry``.
    registry_kwargs:
        Kwargs for a factory-style registry spec.
    token:
        Handshake auth token, shared by the whole fleet (default:
        ``$REPRO_RPC_TOKEN``). Remote workers must have been started with
        the same token. When unset, locally *spawned* workers still get a
        random per-frontend token (the frontend controls both ends, so
        local listeners are never left open to other users on this host);
        remote attaches then handshake with no token.
    transport:
        ``"tcp"`` | ``"shm"`` | ``"auto"`` (default:
        ``$REPRO_RPC_TRANSPORT`` or auto). ``auto`` negotiates a
        shared-memory tensor data plane with locally *spawned* workers
        only; ``shm`` attempts it for every worker; a failed negotiation
        always falls back to TCP (counted in ``stats()["frontend"]
        ["shm_fallbacks"]``). The worker's own policy (its env/CLI) can
        refuse independently.
    window:
        Max batch frames in flight per worker connection (default:
        ``$REPRO_RPC_WINDOW`` or 8).
    shm_bytes:
        Per-direction shm ring size in bytes (default:
        ``$REPRO_RPC_SHM_BYTES`` or 64 MiB).
    ship_artifacts:
        Ship held compiled artifacts to workers at (re-)registration.
        Default: on, unless ``REPRO_SHIP_ARTIFACTS=0``.
    start_method:
        ``multiprocessing`` start method for local workers; ``"spawn"``
        (default) gives every worker a fresh, fork-safety-free jax runtime.
    shutdown_grace:
        Seconds :meth:`close` waits at each escalation step
        (join → terminate → kill) before moving to the next.
    max_batch / max_wait_ms / pool_capacity / fuse / continuous:
        Forwarded to every locally spawned worker's ``RegionServer``
        (remote workers configure theirs at bootstrap); ``continuous``
        selects iteration-level vs request-level batching worker-side
        (``None`` honours each worker's ``REPRO_CONTINUOUS``).
    """

    def __init__(self, workers: int | Sequence[str] | None = None, *,
                 registry: Any, registry_kwargs: Mapping[str, Any] | None = None,
                 max_batch: int = 8, max_wait_ms: float = 2.0,
                 pool_capacity: int = 64, fuse: bool | str = "auto",
                 continuous: bool | None = None,
                 ship_artifacts: bool | None = None,
                 token: str | None = None,
                 transport: str | None = None,
                 window: int | None = None,
                 shm_bytes: int | None = None,
                 start_method: str = "spawn",
                 spawn_timeout: float = 120.0,
                 shutdown_grace: float = 10.0,
                 heartbeat_secs: float | None = None,
                 lease_misses: int | None = None,
                 respawn_max: int | None = None,
                 request_deadline: float | None = None,
                 retry_budget: int | None = None,
                 name: str = "cluster-frontend"):
        # Arm any env-shipped chaos plan with the frontend role before the
        # fleet spawns (spawned workers inherit the same env and arm as
        # "worker" — one export faults both tiers deterministically).
        _faults.init_from_env("frontend")
        if workers is None:
            workers = int(os.environ.get(_WORKERS_ENV, "2"))
        if isinstance(workers, int):
            if workers < 1:
                raise ValueError(f"need at least one worker, got {workers}")
            specs: list[tuple[str, int] | None] = [None] * workers
        else:
            specs = [parse_worker_spec(s) for s in workers]
            if not specs:
                raise ValueError("need at least one worker spec")
        n_local = sum(1 for s in specs if s is None)
        if ship_artifacts is None:
            ship_artifacts = os.environ.get(_SHIP_ENV, "1").strip().lower() \
                not in ("0", "false", "off", "no")
        if n_local and not isinstance(registry, str):
            raise ValueError(
                "registry must be an importable 'module:attr' string when "
                "the fleet spawns local workers — a live TaskFnRegistry "
                "cannot cross the process boundary")
        if token is None:
            token = os.environ.get(_TOKEN_ENV) or None
        # Locally SPAWNED workers are always authenticated: the frontend
        # starts them, so when no token is configured it mints a private
        # one rather than leaving a listener on this host open to any
        # local user. Remote attaches use the configured token as-is
        # (possibly None — the remote worker decides its own auth).
        local_token = token if token is not None else secrets.token_hex(16)
        self.name = name
        self.n_workers = len(specs)
        self.n_remote = len(specs) - n_local
        self.ship_artifacts = ship_artifacts
        self.transport = rpc.transport_mode(transport)
        self.window = rpc.window_size(window)
        self._shm_bytes = (rpc.shm_ring_bytes(shm_bytes)
                           if self.transport in ("shm", "auto") else None)
        self.registry_spec = registry if isinstance(registry, str) else None
        self.registry_kwargs = dict(registry_kwargs or {})
        self.local_registry = resolve_registry(registry, registry_kwargs)
        self.router = StickyRouter(self.n_workers)
        self.shutdown_grace = shutdown_grace
        self._token = token
        self._local_token = local_token
        self._server_kwargs = {"max_batch": max_batch,
                               "max_wait_ms": max_wait_ms,
                               "pool_capacity": pool_capacity, "fuse": fuse,
                               "continuous": continuous}
        self._ids = itertools.count(1)
        self._lock = threading.Lock()
        self._tenants: dict[str, _TenantRecord] = {}
        # Pin groups: identity-keyed frontend registry of pinned buffer
        # sets, shipped to each worker at most once so tenants sharing a
        # group alias ONE decoded copy worker-side (broadcast, not stack).
        self._pin_ids: dict[tuple, str] = {}
        self._pin_data: dict[str, dict] = {}
        self._shipped_pins: set[tuple[int, str]] = set()
        self._closed = False
        self.worker_deaths = 0
        self.requeues = 0
        self.artifacts_shipped = 0
        self.artifact_bytes_shipped = 0
        self.pin_groups_shipped = 0
        # Self-healing knobs (ctor beats env beats default). heartbeat=0
        # disables the supervisor entirely; respawn_max bounds restart
        # attempts per worker slot; request_deadline<=0 means unbounded.
        self._hb_secs = rpc.heartbeat_secs(heartbeat_secs)
        self._lease_misses = rpc.lease_misses(lease_misses)
        self._respawn_max = (respawn_max if respawn_max is not None
                             else _env_int(_RESPAWN_ENV, 3))
        deadline_default = _env_float(_DEADLINE_ENV, 120.0)
        self._request_deadline = (request_deadline
                                  if request_deadline is not None
                                  else deadline_default)
        self._retry_budget = (retry_budget if retry_budget is not None
                              else _env_int(_RETRY_ENV, 2))
        self.retries = 0
        self.respawns = 0
        self.respawn_failures = 0
        self.heartbeat_misses = 0
        self.deadline_failures = 0
        self._respawn_state: dict[int, dict] = {}
        self._spawn_timeout = spawn_timeout
        local_spawner = (LocalSpawner(self.registry_spec,
                                      self.registry_kwargs,
                                      self._server_kwargs, local_token,
                                      start_method=start_method,
                                      transport=self.transport,
                                      shm_bytes=self._shm_bytes)
                         if n_local else None)
        self._local_spawner = local_spawner     # retained for respawns
        remote_spawner = (RemoteSpawner(token, transport=self.transport,
                                        shm_bytes=self._shm_bytes)
                          if self.n_remote else None)
        # Launch every local process before waiting on any port: worker
        # cold start (fresh interpreter + jax import) is seconds each, and
        # overlapping the spawns makes frontend startup cost ~one cold
        # start, not N. Remote workers are already up — attaching is just
        # connect + handshake.
        pendings: list[tuple | None] = []
        for idx, spec in enumerate(specs):
            pendings.append(local_spawner.launch(idx, f"{name}-worker-{idx}")
                            if spec is None else None)
        self._handles: list[_WorkerHandle] = []
        try:
            for idx, (spec, pending) in enumerate(zip(specs, pendings)):
                if spec is None:
                    spawned = local_spawner.connect(pending, spawn_timeout)
                else:
                    spawned = remote_spawner.attach(idx, spec[0], spec[1],
                                                    spawn_timeout)
                self._handles.append(_WorkerHandle(idx, spawned, self._ids,
                                                   self._note_death,
                                                   window=self.window))
        except Exception:
            for h in self._handles:
                h.close()
            for pending in pendings:
                if pending is None:
                    continue
                proc = pending[1]
                if proc.is_alive():
                    proc.terminate()
                    proc.join(timeout=shutdown_grace)
                if proc.is_alive():
                    proc.kill()
                    proc.join(timeout=shutdown_grace)  # reap, don't zombie
            raise
        # The supervisor: a single daemon thread that ticks every
        # heartbeat_secs — probing leases, sweeping expired deadlines, and
        # respawning declared-dead local workers. One thread for the whole
        # fleet (not per-worker): probes are answered inline on the
        # worker's connection thread, so a tick is N cheap sends.
        self._supervisor_stop = threading.Event()
        self._supervisor: threading.Thread | None = None
        if self._hb_secs > 0:
            self._supervisor = threading.Thread(
                target=self._supervise, name="cluster-supervisor",
                daemon=True)
            self._supervisor.start()

    # ------------------------------------------------------------ supervisor
    def _supervise(self) -> None:
        """Lease probes + deadline sweep + respawn, every heartbeat tick.

        The lease is what distinguishes *dead* from *slow*: a worker busy
        with replay still answers heartbeats inline on its connection
        thread, so only ``lease_misses`` consecutive silent ticks —
        ``heartbeat_secs × lease_misses`` of total silence — expire the
        lease and declare the worker dead proactively, instead of waiting
        for a socket error that a wedged-but-connected process never
        produces.
        """
        while not self._supervisor_stop.wait(self._hb_secs):
            if self._closed:
                return
            now = time.monotonic()
            for h in list(self._handles):
                if h.alive:
                    self.deadline_failures += h.expire_deadlines(now)
                    before = h.heartbeat_misses_total
                    expired = h.heartbeat_tick(self._lease_misses)
                    self.heartbeat_misses += h.heartbeat_misses_total - before
                    if expired:
                        h._mark_dead()
                if not h.alive and h.kind == "local" and not self._closed:
                    self._maybe_respawn(h)

    def _maybe_respawn(self, handle: "_WorkerHandle") -> None:
        """Restart a dead local worker's slot, warm, with capped backoff.

        The replacement comes back *warm*: every tenant routed to this slot
        is re-registered with the frontend-held TDG + artifact bytes, so
        its first request hydrates instead of re-lowering. The new handle
        is only published after re-registration — a submit racing the
        respawn either sees the dead handle (and fails over / retries) or
        a fully re-registered live one, never a half-registered worker.
        """
        idx = handle.idx
        state = self._respawn_state.setdefault(
            idx, {"attempts": 0, "next": 0.0})
        now = time.monotonic()
        if (self._local_spawner is None or handle.spawned.spawner is None
                or state["attempts"] >= self._respawn_max
                or now < state["next"]):
            return
        state["attempts"] += 1
        delay = min(_BACKOFF_CAP,
                    _BACKOFF_BASE * (2 ** (state["attempts"] - 1)))
        state["next"] = now + delay * (1.0 + random.random())
        try:
            spawned = handle.spawned.respawn(timeout=self._spawn_timeout)
        except Exception:
            self.respawn_failures += 1
            return
        if self._closed:        # close() won the race; don't leak the child
            try:
                spawned.conn.close()
            finally:
                if spawned.process is not None:
                    spawned.process.terminate()
                    spawned.process.join(timeout=self.shutdown_grace)
                    if spawned.process.is_alive():
                        spawned.process.kill()
            return
        new_handle = _WorkerHandle(idx, spawned, self._ids,
                                   self._note_death, window=self.window)
        with self._lock:
            # The replacement is a blank process: every pin group must
            # re-ship on next reference.
            self._shipped_pins = {(w, k) for (w, k) in self._shipped_pins
                                  if w != idx}
            routed = [r for r in self._tenants.values() if r.worker == idx]
        try:
            for record in routed:
                self._register_on(idx, record, handle=new_handle)
        except Exception:
            # Re-registration failed (replacement died immediately?):
            # count it, tear the new handle down, leave the slot dead for
            # the next tick's backoff.
            self.respawn_failures += 1
            new_handle.close()
            return
        self._handles[idx] = new_handle
        state["attempts"] = 0       # healthy again: reset the backoff
        self.respawns += 1
    def __enter__(self) -> "ClusterFrontend":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        """Shut down the fleet; local processes are *guaranteed* reaped.

        Every worker gets a best-effort shutdown RPC and a connection
        close. For a locally spawned worker that is where best-effort
        ends: a process that ignores the RPC and survives
        ``join(shutdown_grace)`` is escalated to ``terminate()`` (SIGTERM)
        and then ``kill()`` (SIGKILL, unmaskable), and a survivor even of
        that raises :class:`ClusterError` — a leaked jax worker holds
        device memory and a port, so "probably exited" is not an
        acceptable postcondition. Remote workers are not ours to reap: the
        shutdown RPC + close is all the frontend can (and should) do;
        their lifecycle belongs to whoever bootstrapped them.
        """
        with self._lock:
            if self._closed:
                return
            self._closed = True
        # Stop the supervisor BEFORE touching handles: a respawn racing
        # the teardown would re-create workers we are about to reap.
        self._supervisor_stop.set()
        if self._supervisor is not None:
            self._supervisor.join(timeout=max(self.shutdown_grace,
                                              2 * self._hb_secs + 5.0))
        for h in self._handles:
            if h.alive:
                try:
                    h.request({"op": "shutdown"}, timeout=30.0)
                except Exception:       # dying worker: we're tearing down
                    pass
            h.close()
        leaked = []
        for h in self._handles:
            if h.process is None:       # remote: RPC + close was the job
                continue
            h.process.join(timeout=self.shutdown_grace)
            if h.process.is_alive():
                h.process.terminate()
                h.process.join(timeout=self.shutdown_grace)
            if h.process.is_alive():
                h.process.kill()
                h.process.join(timeout=self.shutdown_grace)
            if h.process.is_alive():
                leaked.append(h)
        if leaked:
            raise ClusterError(
                "leaked worker process(es) survived terminate+kill: "
                + ", ".join(f"worker {h.idx} (pid {h.process.pid})"
                            for h in leaked))

    def _note_death(self, idx: int) -> None:
        with self._lock:
            if not self._closed:     # orderly shutdown is not a death
                self.worker_deaths += 1

    def _alive(self) -> set[int]:
        return {h.idx for h in self._handles if h.alive}

    # --------------------------------------------------------------- tenants
    def register_tenant(self, name: str, tdg: TDG | None = None, *,
                        outputs: tuple[str, ...] | None = None,
                        kernel_mode: str | None = None,
                        warm_path: str | None = None,
                        pinned: Mapping[str, Any] | None = None,
                        tier: int | None = None,
                        rate: float | None = None
                        ) -> _TenantRecord:
        """Route + register a tenant on its structure-sticky worker.

        ``tier`` / ``rate`` are the tenant's QoS config (priority tier and
        token-bucket req/s); they ship with the registration so the worker
        enforces them at ITS admission queue, and re-ship on every
        failover/respawn re-registration. ``None`` defers to the worker's
        ``REPRO_TENANT_TIER`` / ``REPRO_TENANT_RATE`` environment.

        Exactly one of ``tdg`` / ``warm_path`` selects the region source,
        mirroring ``RegionServer.register_tenant``. With ``warm_path``, the
        frontend reads the TDG JSON *and* the ``.aot`` sidecar bytes; the
        sidecar ships in-band so the worker hydrates instead of
        re-lowering. ``pinned`` buffers (e.g. model params) are grouped by
        object identity and shipped at most once per worker; tenants
        passing the same objects alias one decoded copy worker-side (so
        the coalescer broadcasts them instead of stacking), and ``submit``
        only carries the varying slots.
        """
        if (tdg is None) == (warm_path is None):
            raise ValueError("pass exactly one of tdg= or warm_path=")
        artifact = None
        if warm_path is not None:
            with open(warm_path) as f:
                tdg_dict = json.load(f)
            tdg = _serialize.tdg_from_dict(tdg_dict, self.local_registry)
            aot_path = str(warm_path) + ".aot"
            if os.path.exists(aot_path):
                with open(aot_path, "rb") as f:
                    artifact = f.read()
        else:
            tdg.validate()
            tdg_dict = _serialize.tdg_to_dict(tdg, self.local_registry)
        from ..kernels import registry as _kreg

        mode = _kreg.resolved_mode(kernel_mode)
        sig, _slot_map, payloads = structure_signature(
            tdg, list(outputs) if outputs is not None else None)
        route_key = (sig, tuple(self.local_registry.name_of(p)
                                for p in payloads), mode)
        record = _TenantRecord(name, tdg_dict,
                               tuple(outputs) if outputs else None,
                               mode, route_key,
                               tier=(None if tier is None
                                     else max(0, int(tier))),
                               rate=(None if rate is None
                                     else max(0.0, float(rate))))
        record.artifact = artifact
        if pinned is not None:
            record.pin_key = self._pin_group_for(dict(pinned))
        with self._lock:
            if name in self._tenants:
                raise ValueError(f"tenant {name!r} already registered")
            self._tenants[name] = record
        try:
            widx = self.router.route(route_key, self._alive())
            self._register_on(widx, record)
        except Exception:
            # Leave no phantom behind: a failed registration must be
            # retryable under the same name after the caller fixes it.
            with self._lock:
                self._tenants.pop(name, None)
            raise
        return record

    def _pin_group_for(self, pinned: dict) -> str:
        """The identity-keyed pin group for this buffer set (created once).

        Two tenants registering with the *same objects* (e.g. one params
        pytree) resolve to the same group, so the data crosses the wire
        once per worker and every tenant aliases one decoded copy there.
        The group dict pins strong refs, which keeps the ``id()`` key sound.
        """
        ident = tuple(sorted((k, id(v)) for k, v in pinned.items()))
        with self._lock:
            key = self._pin_ids.get(ident)
            if key is None:
                key = f"pin{len(self._pin_ids)}"
                self._pin_ids[ident] = key
                self._pin_data[key] = pinned
            return key

    def _register_on(self, widx: int, record: _TenantRecord,
                     handle: "_WorkerHandle | None" = None) -> dict:
        # ``handle`` overrides the published table during a respawn: the
        # replacement must be fully registered BEFORE it appears in
        # self._handles (submits racing the respawn must never see a
        # half-registered worker).
        msg = {"op": "register", "tenant": record.name,
               "tdg": record.tdg_dict,
               "outputs": list(record.outputs) if record.outputs else None,
               "kernel_mode": record.kernel_mode,
               "pin_key": record.pin_key,
               "tier": record.tier, "rate": record.rate}
        ship_pin = False
        if record.pin_key is not None:
            with self._lock:
                ship_pin = (widx, record.pin_key) not in self._shipped_pins
            if ship_pin:
                msg["pinned"] = self._pin_data[record.pin_key]
        if self.ship_artifacts and record.artifact is not None:
            artifact = record.artifact
            if _faults.ENABLED:
                # Chaos hook: a "corrupt" rule poisons the shipped bytes —
                # the worker must reject them loudly (aot_hydrate_failures)
                # and re-lower, never crash.
                artifact = _faults.corrupt_artifact(artifact)
            msg["artifact"] = artifact
        reply = (handle if handle is not None
                 else self._handles[widx]).request(msg)
        record.worker = widx
        with self._lock:
            if ship_pin:
                self._shipped_pins.add((widx, record.pin_key))
                self.pin_groups_shipped += 1
            if msg.get("artifact") is not None:
                self.artifacts_shipped += 1
                self.artifact_bytes_shipped += len(record.artifact)
        return reply

    def tenant(self, name: str) -> _TenantRecord:
        with self._lock:
            if name not in self._tenants:
                raise KeyError(f"unknown tenant {name!r}; registered: "
                               f"{sorted(self._tenants)}")
            return self._tenants[name]

    def warmup(self, name: str, buffers: Mapping[str, Any],
               timeout: float | None = 600.0) -> dict:
        """AOT-compile ``name`` on its worker; hold the artifact for shipping.

        The worker returns the compiled executable as bytes; the frontend
        keeps them on the tenant record so a *future* worker (failover
        sibling, or a scale-out registration) hydrates instead of paying
        trace+compile again. Returns the worker's compile report.
        """
        record = self.tenant(name)
        widx = self._worker_for(record)
        reply = self._handles[widx].request(
            {"op": "warmup", "tenant": name, "buffers": dict(buffers)},
            timeout=timeout)
        if reply.get("artifact") is not None:
            record.artifact = reply["artifact"]
        return reply["report"]

    # ------------------------------------------------------------ admission
    def _worker_for(self, record: _TenantRecord) -> int:
        """The tenant's current worker, failing over if it died."""
        widx = record.worker
        if widx is not None and self._handles[widx].alive:
            return widx
        return self._failover(record, exclude={widx} if widx is not None
                              else set())

    def _failover(self, record: _TenantRecord, exclude: set[int]) -> int:
        """Re-route ``record`` to a live sibling and re-register it there.

        Counted as a ``requeue`` whether the death was noticed before the
        send (stale ``record.worker``) or mid-flight (a failed future):
        either way this tenant's work just moved to a sibling.
        """
        widx = self.router.reroute(record.route_key, self._alive(), exclude)
        with self._lock:
            self.requeues += 1
        self._register_on(widx, record)
        return widx

    def submit(self, tenant_name: str, buffers: Mapping[str, Any],
               deadline_s: float | None = None) -> Future:
        """RPC front on ``RegionServer.submit``: returns a Future of the
        output buffer dict. A worker death mid-flight requeues the request
        to a sibling (or the slot's respawned replacement) with jittered
        backoff, up to the per-request retry budget; the request's
        deadline bounds the whole affair (``deadline_s`` seconds from now,
        default ``request_deadline`` / ``REPRO_REQUEST_DEADLINE``; pass 0
        to disable). Payloads are pure functions over explicit buffers, so
        a retried request is safe to re-execute.

        This is the frontend's hot path and it takes NO frontend-wide
        lock: the tenant lookup is a GIL-atomic dict read, the closed
        check a plain bool, and the request counter a racy-benign
        increment — many submitting threads proceed in parallel straight
        into their worker's submit queue (the per-worker handoff is the
        only synchronization, and it is a queue append).
        """
        record = self._tenants.get(tenant_name)
        if record is None:
            raise KeyError(f"unknown tenant {tenant_name!r}; registered: "
                           f"{sorted(self._tenants)}")
        if self._closed:
            raise RuntimeError(f"frontend {self.name!r} is closed")
        record.requests += 1
        budget = deadline_s if deadline_s is not None \
            else self._request_deadline
        deadline = (time.monotonic() + budget
                    if budget is not None and budget > 0 else None)
        outer: Future = Future()
        self._submit_attempt(record, dict(buffers), outer,
                             retries=self._retry_budget, deadline=deadline)
        return outer

    def _submit_attempt(self, record: _TenantRecord, buffers: dict,
                        outer: Future, retries: int,
                        deadline: float | None) -> None:
        try:
            widx = self._worker_for(record)
            inner = self._handles[widx].submit_async(record.name, buffers,
                                                     deadline=deadline)
        except WorkerDied as exc:
            self._retry_or_fail(record, buffers, outer, retries, exc,
                                {record.worker} if record.worker is not None
                                else set(), deadline)
            return
        except Exception as exc:
            outer.set_exception(exc)
            return

        def _done(f: Future) -> None:
            exc = f.exception()
            if isinstance(exc, WorkerDied):
                self._retry_or_fail(record, buffers, outer, retries, exc,
                                    {widx}, deadline)
            elif exc is not None:
                # DeadlineExceeded and QueueFull land here too: terminal by
                # design (the deadline has passed / the fleet is telling us
                # to back off — re-dispatching would amplify the overload).
                outer.set_exception(exc)
            else:
                outer.set_result(f.result()["out"])
        inner.add_done_callback(_done)

    def _retry_or_fail(self, record: _TenantRecord, buffers: dict,
                       outer: Future, retries: int, exc: Exception,
                       exclude: set[int], deadline: float | None) -> None:
        """Retry a ``WorkerDied`` request elsewhere, after jittered backoff.

        Runs on reader/callback threads, so it never sleeps: the delay is
        a ``threading.Timer``. The backoff matters on two axes — a mass
        death doesn't thundering-herd the surviving siblings, and it gives
        the supervisor a beat to respawn the slot (the exclusion set is
        re-intersected with the *live* fleet at fire time, so a respawned
        same-slot worker is eligible again — without that, a one-worker
        fleet could never recover).
        """
        if retries <= 0 or (deadline is not None
                            and time.monotonic() >= deadline):
            outer.set_exception(
                exc if deadline is None or time.monotonic() < deadline
                else DeadlineExceeded(
                    f"tenant {record.name!r}: deadline passed during "
                    f"failover ({exc})"))
            return
        with self._lock:
            self.retries += 1
        attempt = self._retry_budget - retries + 1
        delay = min(_BACKOFF_CAP, _BACKOFF_BASE * (2 ** (attempt - 1)))
        delay *= 0.5 + random.random()      # jitter: 0.5x..1.5x

        def _fire() -> None:
            if self._closed:
                outer.set_exception(ClusterError(
                    f"frontend {self.name!r} closed during failover"))
                return
            try:
                excl = set(exclude) & self._alive()
                self._failover(record, exclude=excl)
            except ClusterError:
                # No candidate yet (lone worker still respawning): burn a
                # retry and try again after another backoff.
                self._retry_or_fail(record, buffers, outer, retries - 1,
                                    exc, exclude, deadline)
                return
            except Exception as fail_exc:
                outer.set_exception(fail_exc)
                return
            self._submit_attempt(record, buffers, outer, retries - 1,
                                 deadline)
        t = threading.Timer(delay, _fire)
        t.daemon = True
        t.start()

    def serve(self, tenant_name: str, buffers: Mapping[str, Any],
              timeout: float | None = 120.0) -> dict:
        """Synchronous :meth:`submit`; ``timeout`` doubles as the request
        deadline, so a worker that can never answer yields a typed
        ``DeadlineExceeded`` rather than a bare futures timeout. The wait
        itself gets one supervisor tick of slack past the deadline — the
        sweep is what converts "no reply" into the typed error, and it
        must win the race against the raw futures timeout.
        """
        fut = self.submit(tenant_name, buffers, deadline_s=timeout)
        wait = (timeout + max(2 * self._hb_secs, 1.0)
                if timeout is not None else None)
        return fut.result(timeout=wait)

    # -------------------------------------------------------------- metrics
    def health(self) -> list[dict]:
        """Ping every worker; one row per worker (alive, kind, pid, address).

        ``process_alive`` is ``None`` for remote workers — the frontend has
        no process handle there; liveness is the connection + ping.
        ``topology`` is the fingerprint the worker advertised at handshake.
        """
        rows = []
        for h in self._handles:
            row = {"worker": h.idx, "alive": h.alive, "kind": h.kind,
                   "address": f"{h.address[0]}:{h.address[1]}",
                   "process_alive": (h.process.is_alive()
                                     if h.process is not None else None),
                   "topology": h.info.get("topology")}
            if h.alive:
                try:
                    reply = h.request({"op": "ping"}, timeout=30.0)
                    row.update(pid=reply["pid"], port=reply["port"])
                except Exception:
                    row["alive"] = False
            rows.append(row)
        return rows

    def stats(self) -> dict:
        """Frontend counters + per-worker server stats + cross-worker sums.

        The ``aggregate`` block sums every worker's serving metrics — the
        fields ``docs/serving.md`` glossaries, including
        ``aot_hydrate_failures``, so a worker that silently fell back to
        lazy lowering is visible at the fleet level.
        """
        per_worker: dict[int, dict | None] = {}
        for h in self._handles:
            if not h.alive:
                per_worker[h.idx] = None
                continue
            try:
                per_worker[h.idx] = h.request({"op": "stats"},
                                              timeout=60.0)["stats"]
            except Exception:
                per_worker[h.idx] = None
        metric_keys = ("admitted", "completed", "failed", "batches",
                       "coalesced_requests", "batch_fallbacks", "aot_served",
                       "aot_hydrate_failures", "aot_topology_rejects",
                       "shed", "deadline_sheds", "rate_limited",
                       "joins", "leaves")
        agg = {k: 0 for k in metric_keys}
        pool = {"hits": 0, "misses": 0, "evictions": 0, "hydrations": 0,
                "entries": 0}
        intern = {"hits": 0, "misses": 0, "evictions": 0, "entries": 0}
        hydrated_inband = 0
        for s in per_worker.values():
            if s is None:
                continue
            for k in metric_keys:
                agg[k] += s["metrics"].get(k, 0)
            for k in pool:
                pool[k] += s["pool"].get(k, 0)
            for k in intern:
                intern[k] += s["intern"].get(k, 0)
            hydrated_inband += s["worker"].get("hydrated_inband", 0)
        # Per-worker wire totals as observed from the frontend side of each
        # connection: REAL byte counts in both directions (rpc.RpcConnection
        # accounts frame sizes, not message counts), codec time
        # (encode/decode seconds), shm data-plane bytes, and the
        # dispatcher's framing stats (frames sent, entries per frame,
        # in-flight window occupancy, timeouts) — so a millisecond of
        # per-request overhead is attributable to codec, framing or
        # transport per worker, not a wall-clock mystery.
        wire: dict[int, dict] = {}
        wire_total = {"bytes_sent": 0, "bytes_received": 0,
                      "messages_sent": 0, "messages_received": 0,
                      "encode_seconds": 0.0, "decode_seconds": 0.0,
                      "shm_bytes_sent": 0, "shm_bytes_received": 0,
                      "frames_sent": 0, "entries_sent": 0, "timeouts": 0}
        shm_fallbacks = 0
        for h in self._handles:
            w = {**h.conn.wire_stats(), **h.dispatch_stats()}
            wire[h.idx] = {**w, "kind": h.kind, "shm_fallback": h.shm_fallback,
                           "address": f"{h.address[0]}:{h.address[1]}"}
            for k in wire_total:
                wire_total[k] += w[k]
            shm_fallbacks += 1 if h.shm_fallback else 0
        with self._lock:
            tenants = {r.name: {"worker": r.worker, "requests": r.requests,
                                "has_artifact": r.artifact is not None}
                       for r in self._tenants.values()}
            frontend = {
                "name": self.name,
                "workers": self.n_workers,
                "remote_workers": self.n_remote,
                "alive": len(self._alive()),
                "worker_deaths": self.worker_deaths,
                "requeues": self.requeues,
                "retries": self.retries,
                "respawns": self.respawns,
                "respawn_failures": self.respawn_failures,
                "heartbeat_misses": self.heartbeat_misses,
                "deadline_failures": self.deadline_failures,
                "supervisor": {
                    "enabled": self._hb_secs > 0,
                    "heartbeat_secs": self._hb_secs,
                    "lease_misses": self._lease_misses,
                    "respawn_max": self._respawn_max,
                    "request_deadline": self._request_deadline,
                    "retry_budget": self._retry_budget,
                },
                "artifacts_shipped": self.artifacts_shipped,
                "artifact_bytes_shipped": self.artifact_bytes_shipped,
                "pin_groups_shipped": self.pin_groups_shipped,
                "ship_artifacts": self.ship_artifacts,
                "transport": self.transport,
                "window": self.window,
                "shm_fallbacks": shm_fallbacks,
                "wire": wire_total,
            }
        return {"frontend": frontend, "tenants": tenants,
                "aggregate": {**agg, "pool": pool, "intern": intern,
                              "hydrated_inband": hydrated_inband},
                "workers": per_worker, "wire": wire}

    def trace(self) -> dict:
        """Per-worker execution-pattern trace rings (see metrics.TRACE_SCHEMA).

        Each live worker's ring comes back oldest-first under its index;
        a dead/unreachable worker maps to ``None``. Use this to see step
        occupancy, join/leave churn and stragglers fleet-wide — the
        aggregate counters in :meth:`stats` cannot show a detrimental
        execution *pattern*, only its average."""
        out: dict[int, dict | None] = {}
        for h in self._handles:
            if not h.alive:
                out[h.idx] = None
                continue
            try:
                reply = h.request({"op": "trace"}, timeout=60.0)
                out[h.idx] = {"records": reply["trace"],
                              "summary": reply["summary"]}
            except Exception:
                out[h.idx] = None
        return out
