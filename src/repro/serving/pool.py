"""Warm-executable pool: the shared, LRU-bounded resource of the server.

The paper's whole point is that a recorded TDG region is orchestrated once
and replayed many times; at serving scale the scarce resource becomes the
*compiled executable itself*. This pool holds every executable the server
has produced or hydrated —

* **cross-request batched** callables (one ``vmap``-batched fused replay
  serving a whole admission batch — the server's extension of
  ``fuse._run_fused_class`` semantics from wave-mates to request-mates),
  keyed by the TDG's canonical structure + payload identities + kernel
  mode — never by tenant name — so N tenants with structurally identical
  regions share ONE entry and the first tenant pays for everyone;
* **AOT executables** hydrated from ``.aot`` sidecars
  (``serialize.load_warm``) or produced eagerly by
  ``lower.aot_compile_tdg`` during an explicit warmup. These ARE keyed
  per tenant: a compiled binary's input specs carry that tenant's
  concrete slot names and buffer shapes, so it cannot serve a
  structurally identical neighbour directly.

Single-request replay callables do not live here at all — they are cached
on the tenant and shared *across* tenants by ``lower.py``'s global
structural intern cache, whose ``intern_stats()`` the server reports
alongside this pool's counters.

Entries pin their payload closures (strong refs) exactly like
``lower._InternEntry``: ``id()``-based keys are only sound while the
objects they name stay alive. The pool is LRU-bounded for the same reason
the intern cache is — a server that keeps registering fresh tenants must
not leak executables forever. Hit/miss/eviction counters are the serving
layer's intern-hit-rate metric.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Any, Callable


@dataclasses.dataclass
class PoolEntry:
    """One warm executable.

    ``kind`` is ``"single"`` (per-request replay callable), ``"batched"``
    (stacked/shared-buffer batch callable) or ``"aot"`` (an
    ``lower.AotExecutable``). ``payloads`` pins the task payload functions
    whose ``id()``s appear in the pool key.
    """

    kind: str
    fn: Callable[..., Any]
    payloads: tuple = ()
    meta: dict = dataclasses.field(default_factory=dict)
    hits: int = 0


class WarmPool:
    """LRU-bounded map: executable key -> :class:`PoolEntry`."""

    def __init__(self, capacity: int = 64):
        self.capacity = max(1, capacity)
        self._lock = threading.Lock()
        self._entries: collections.OrderedDict[tuple, PoolEntry] = \
            collections.OrderedDict()
        self._counters = {"hits": 0, "misses": 0, "evictions": 0,
                          "hydrations": 0, "invalidations": 0}

    def get(self, key: tuple) -> PoolEntry | None:
        """Look up ``key``, counting a hit (and refreshing LRU) or a miss."""
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self._counters["misses"] += 1
                return None
            self._counters["hits"] += 1
            entry.hits += 1
            self._entries.move_to_end(key)
            return entry

    def put(self, key: tuple, entry: PoolEntry,
            hydrated: bool = False) -> PoolEntry:
        """Install ``entry`` under ``key`` (first writer wins on a race).

        Returns the entry actually stored, so two threads that compiled the
        same structure concurrently converge on one executable. Evicts
        least-recently-used entries beyond ``capacity``.
        """
        with self._lock:
            stored = self._entries.setdefault(key, entry)
            self._entries.move_to_end(key)
            if hydrated and stored is entry:
                self._counters["hydrations"] += 1
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self._counters["evictions"] += 1
            return stored

    def peek(self, key: tuple) -> PoolEntry | None:
        """Like :meth:`get` but without touching counters or LRU order."""
        with self._lock:
            return self._entries.get(key)

    def invalidate(self, predicate: Callable[[tuple, PoolEntry], bool]) -> int:
        """Drop every entry for which ``predicate(key, entry)`` is true.

        Returns the number removed (also counted in ``invalidations``,
        distinct from capacity ``evictions``). This is how the adaptive
        bucket tuner retires stale *batched* executables after a boundary
        refit: their baked-in bucket sizes no longer match what the
        scheduler will request, so keeping them warm only wastes pool
        capacity on entries that can never hit again.
        """
        with self._lock:
            dead = [k for k, e in self._entries.items() if predicate(k, e)]
            for k in dead:
                del self._entries[k]
            self._counters["invalidations"] += len(dead)
            return len(dead)

    def stats(self) -> dict:
        """Hit/miss/eviction/hydration counters + current entry count.

        ``hot`` is the per-entry hit distribution (kind + hits per live
        entry, hottest first): under continuous batching it is how you
        verify membership churn keeps re-slicing the SAME pooled
        executables — a churn-driven retrace shows up as many one-hit
        entries instead of a few hot ones.
        """
        with self._lock:
            hot = sorted(({"kind": e.kind, "hits": e.hits}
                          for e in self._entries.values()),
                         key=lambda r: -r["hits"])
            return {**self._counters, "entries": len(self._entries),
                    "hot": hot}

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            for k in self._counters:
                self._counters[k] = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)
