"""Multi-tenant taskgraph region server (the serving tier over replay).

The record-and-replay model exists so a region is orchestrated once and
replayed with near-zero management overhead; this module is the step from
"replay one region fast" to "serve many tenants fast". Following the
async-manager shape of Bosch et al. (arXiv:2009.03066) — clients enqueue
work, one manager thread owns dispatch — a :class:`RegionServer` accepts
requests against registered *tenants* (a named TDG + pinned kernel mode)
through an **admission queue** and serves them from shared compiled
executables:

* **Coalescing.** Concurrent requests whose TDGs canonicalize to the same
  ``tdg.structure_signature`` (and same payload identities, buffer shapes
  and kernel mode) are batched into ONE fused replay: buffers are stacked
  along a fresh leading axis and the canonical region function is
  ``vmap``-ed across *requests* — the same trick ``fuse._run_fused_class``
  plays across wave-mates, lifted across tenants. Buffers that are the
  *same object* in every member request (e.g. shared model params) are
  broadcast, not stacked. A batch whose payloads refuse to vmap falls back
  to per-request replay for that batch only.
* **Warm pool.** Batched callables live in an LRU-bounded
  :class:`~repro.serving.pool.WarmPool` keyed by structure + payload
  identities + kernel mode — never by tenant name — so N structurally
  identical tenants share one entry; AOT executables live there too,
  keyed per tenant (their compiled input specs name that tenant's
  slots/shapes). Single-request replay goes through
  ``lower.lower_tdg``'s global structural intern cache, so tenant
  #2..#N reuse tenant #1's jitted executable (``intern_stats()`` counts
  the hits). Cold tenants registered with a ``warm_path`` hydrate their
  compiled binary from the ``.aot`` sidecar (``serialize.load_warm``)
  instead of retracing.
* **Isolation.** Payload identities partition the coalescing key: two
  tenants with same-shaped graphs over *different* payload closures never
  share an executable or a batch. Each tenant's kernel substrate is
  resolved once at registration and re-entered as a
  ``kernel_mode_scope`` around every lowering and call (exactly
  ``ReplayExecutor``'s pinning), so a global ``REPRO_KERNELS`` flip cannot
  change what an already-registered tenant executes.
* **Metrics.** Queue depth, batch occupancy, pool hit rate, p50/p99
  replay latency — see :mod:`repro.serving.metrics`.
"""
from __future__ import annotations

import collections
import dataclasses
import os
import threading
import time
from concurrent.futures import Future
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp

from ..core import lower as _lower
from ..core import serialize as _serialize
from ..core.tdg import TDG, buffers_signature, structure_signature
from ..kernels import registry as _kreg
from .metrics import ServerMetrics
from .pool import PoolEntry, WarmPool

#: Admission-queue bound (requests). ``0`` / unset = unbounded (the
#: pre-backpressure behaviour). When the queue is at the bound, new
#: submissions are refused with :class:`QueueFull` instead of growing the
#: queue without limit under overload.
QUEUE_BOUND_ENV = "REPRO_QUEUE_BOUND"


class QueueFull(RuntimeError):
    """Admission refused: the server's bounded queue is at capacity.

    This is the load-shedding signal — the submitter should back off or
    route elsewhere. Deliberately a *typed* error so the cluster frontend
    can tell backpressure (don't retry the same worker immediately) from a
    worker fault (retry a sibling)."""


class DeadlineExceeded(RuntimeError):
    """The request's deadline passed before a result could be produced.

    Raised into the request future either at admission/dispatch time (the
    request was shed unexecuted — see ``deadline_sheds``) or by the cluster
    frontend's deadline sweep when a reply never arrived. Terminal: the
    retry machinery never retries past a deadline."""


def queue_bound_default() -> int:
    """The env-configured admission bound (0 = unbounded)."""
    raw = os.environ.get(QUEUE_BOUND_ENV, "").strip()
    return max(0, int(raw)) if raw else 0


@dataclasses.dataclass
class Tenant:
    """One registered tenant: a region (TDG) plus its pinned substrate.

    ``sig``/``slot_map``/``payloads`` are the canonical structure computed
    once at registration; ``kernel_mode`` is the *resolved* substrate
    (never ``"auto"``), chosen at registration exactly like
    ``ReplayExecutor`` pins it at construction.
    """

    name: str
    tdg: TDG
    outputs: tuple[str, ...] | None
    kernel_mode: str
    sig: tuple
    slot_map: dict[str, str]
    payloads: tuple
    warm_path: str | None = None
    fuse: bool | str = "auto"
    aot_key: tuple | None = None
    aot_sig: tuple | None = None
    requests: int = 0

    def __post_init__(self) -> None:
        self.payload_ids = tuple(id(p) for p in self.payloads)
        self.from_canon = {c: a for a, c in self.slot_map.items()}
        self.input_slots = tuple(s for s in self.tdg.input_slots
                                 if s in self.slot_map)
        self._fn: Callable[[dict], dict] | None = None
        self._fn_lock = threading.Lock()

    def replay_fn(self) -> Callable[[dict], dict]:
        """The (lazily built) single-request replay callable.

        Built via ``lower.lower_tdg`` under this tenant's pinned mode, so
        it lands in — or is served from — the global structural intern
        cache shared with every other structurally identical tenant.
        """
        with self._fn_lock:
            if self._fn is None:
                with _kreg.kernel_mode_scope(self.kernel_mode):
                    self._fn = _lower.lower_tdg(
                        self.tdg, fuse=self.fuse,
                        outputs=list(self.outputs)
                        if self.outputs is not None else None)
            return self._fn


class _Request:
    __slots__ = ("tenant", "buffers", "canon_buffers", "key", "future",
                 "t_submit", "served_aot", "deadline")

    def __init__(self, tenant: Tenant, buffers: dict, canon_buffers: dict,
                 key: tuple, deadline: float | None = None):
        self.tenant = tenant
        self.buffers = buffers
        self.canon_buffers = canon_buffers
        self.key = key
        self.future: Future = Future()
        self.t_submit = time.monotonic()
        self.served_aot = False
        self.deadline = deadline       # absolute time.monotonic(), or None


class RegionServer:
    """Admission-queued, batch-coalescing server over interned replay.

    Parameters
    ----------
    max_batch:
        Coalescing ceiling — how many structurally identical requests one
        fused replay may carry. ``1`` disables batching (serial
        per-request replay; the benchmark baseline).
    max_wait_ms:
        Admission window: after the first request of a batch arrives, how
        long the dispatcher waits for same-structure companions before
        dispatching a partial batch. Bounded head-of-line latency.
    pool_capacity:
        LRU bound on the warm-executable pool.
    queue_bound:
        Admission-queue bound (requests). ``None`` honours
        ``REPRO_QUEUE_BOUND``; ``0`` means unbounded. At the bound, new
        submissions are refused with :class:`QueueFull` (counted in the
        ``shed`` metric) instead of growing the queue under overload.
    fuse:
        Wave-fusion policy handed to every lowering this server performs
        (single-request AND batched paths): ``True`` / ``False`` /
        ``"auto"`` (honour ``REPRO_FUSE``), as in ``lower.lower_tdg``.
    autostart:
        Start the dispatcher thread immediately. Tests pass ``False``,
        enqueue a known set of requests, then call :meth:`start` for a
        deterministic first batch.
    """

    def __init__(self, max_batch: int = 8, max_wait_ms: float = 2.0,
                 pool_capacity: int = 64, fuse: bool | str = "auto",
                 name: str = "region-server", autostart: bool = True,
                 queue_bound: int | None = None):
        self.name = name
        self.max_batch = max(1, int(max_batch))
        self.max_wait_s = max(0.0, float(max_wait_ms)) / 1e3
        self.queue_bound = (queue_bound_default() if queue_bound is None
                            else max(0, int(queue_bound)))
        self.fuse = fuse
        self.pool = WarmPool(capacity=pool_capacity)
        self.metrics = ServerMetrics()
        self._tenants: dict[str, Tenant] = {}
        self._queue: collections.deque[_Request] = collections.deque()
        self._cv = threading.Condition()
        self._closed = False
        self._started = False
        self._thread = threading.Thread(
            target=self._dispatch_loop, name=f"{name}-dispatch", daemon=True)
        if autostart:
            self.start()

    # ------------------------------------------------------------ lifecycle
    def start(self) -> None:
        """Start the dispatcher thread (idempotent)."""
        if not self._started:
            self._started = True
            self._thread.start()

    def close(self) -> None:
        """Drain the admission queue, then stop the dispatcher.

        Holds even for a never-started server (``autostart=False``) with
        requests already queued: the dispatcher is started just to drain
        them, so no pending future is ever silently abandoned.
        """
        with self._cv:
            self._closed = True
            self._cv.notify_all()
            pending = bool(self._queue)
        if not self._started and pending:
            self.start()
        if self._started:
            self._thread.join()

    def __enter__(self) -> "RegionServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------- tenants
    def register_tenant(self, name: str, tdg: TDG | None = None, *,
                        outputs: tuple[str, ...] | None = None,
                        kernel_mode: str | None = None,
                        warm_path: str | None = None,
                        fn_registry: "_serialize.TaskFnRegistry | None" = None,
                        ) -> Tenant:
        """Register a tenant by TDG, or hydrate one from a warm artifact.

        Exactly one of ``tdg`` / ``warm_path`` selects the region source:
        ``warm_path`` names a TDG JSON written by
        ``serialize.warmup_and_save`` (payloads re-linked through
        ``fn_registry``); if its ``.aot`` sidecar is present and loadable,
        the compiled binary is installed in the warm pool so this tenant's
        first request replays without any retrace. A missing or corrupt
        sidecar degrades silently to the ordinary (interned, lazily
        traced) replay path — hydration is an optimization, never a
        correctness dependency.
        """
        if (tdg is None) == (warm_path is None):
            raise ValueError("pass exactly one of tdg= or warm_path=")
        aot = None
        sidecar_present = False
        if warm_path is not None:
            if fn_registry is None:
                raise ValueError("warm_path= requires fn_registry= to "
                                 "re-link task payloads")
            sidecar_present = os.path.exists(str(warm_path) + ".aot")
            tdg, aot = _serialize.load_warm(warm_path, fn_registry)
        tdg.validate()
        mode = _kreg.resolved_mode(kernel_mode)
        sig, slot_map, payloads = structure_signature(
            tdg, list(outputs) if outputs is not None else None)
        tenant = Tenant(name=name, tdg=tdg,
                        outputs=tuple(outputs) if outputs is not None else None,
                        kernel_mode=mode, sig=sig, slot_map=slot_map,
                        payloads=payloads, warm_path=warm_path,
                        fuse=self.fuse)
        with self._cv:
            if name in self._tenants:
                raise ValueError(f"tenant {name!r} already registered")
            self._tenants[name] = tenant
        if aot is not None:
            self._install_aot(tenant, aot, hydrated=True)
        elif sidecar_present:
            # The sidecar was on disk but load_warm soft-fell back (corrupt,
            # truncated, platform/version mismatch, or a jax build without
            # executable serialization). The tenant still works — lazily
            # traced — but it is NOT warm, and pretending otherwise is how
            # cold-start regressions hide. Make the fallback loud in metrics.
            self.metrics.on_aot_hydrate_failure()
        return tenant

    def tenant(self, name: str) -> Tenant:
        with self._cv:
            if name not in self._tenants:
                raise KeyError(f"unknown tenant {name!r}; registered: "
                               f"{sorted(self._tenants)}")
            return self._tenants[name]

    def warmup(self, name: str, buffers: Mapping[str, Any]) -> dict:
        """Eagerly AOT-compile a tenant's replay executable into the pool.

        ``buffers`` may be concrete arrays or ``ShapeDtypeStruct`` specs.
        Returns the compile report (cost analysis, trace/compile seconds)
        so callers can budget warmup off the serving critical path.
        """
        tenant = self.tenant(name)
        with _kreg.kernel_mode_scope(tenant.kernel_mode):
            aot = _lower.aot_compile_tdg(
                tenant.tdg, buffers, fuse=tenant.fuse,
                outputs=list(tenant.outputs)
                if tenant.outputs is not None else None)
        self._install_aot(tenant, aot)
        return {"tenant": name, "fused": aot.fused,
                "cost_analysis": aot.cost_analysis,
                "trace_seconds": aot.trace_seconds,
                "compile_seconds": aot.compile_seconds}

    def install_aot(self, name: str, aot: "_lower.AotExecutable",
                    hydrated: bool = False) -> None:
        """Install an externally produced AOT executable for tenant ``name``.

        This is how the cluster tier's :class:`~repro.serving.cluster.
        WorkerNode` plants an executable hydrated from *shipped* artifact
        bytes (``serialize.executable_from_bytes``) — the worker never
        re-lowers what the frontend already compiled. ``hydrated=True``
        counts it in the pool's hydration counter.
        """
        self._install_aot(self.tenant(name), aot, hydrated=hydrated)

    def _install_aot(self, tenant: Tenant, aot: "_lower.AotExecutable",
                     hydrated: bool = False) -> None:
        aot_sig = buffers_signature(aot.input_specs)
        key = ("aot", tenant.name, aot_sig, tenant.kernel_mode)
        self.pool.put(key, PoolEntry("aot", aot, tenant.payloads),
                      hydrated=hydrated)
        tenant.aot_key = key
        tenant.aot_sig = aot_sig

    # ------------------------------------------------------------ admission
    def _make_request(self, tenant_name: str, buffers: Mapping[str, Any],
                      deadline: float | None = None) -> "_Request":
        """Validate + canonicalize one submission into a queue entry."""
        tenant = self.tenant(tenant_name)
        missing = [s for s in tenant.input_slots if s not in buffers]
        if missing:
            raise KeyError(f"request for tenant {tenant_name!r} is missing "
                           f"input slots {missing}")
        buffers = dict(buffers)
        canon = {tenant.slot_map[k]: v for k, v in buffers.items()
                 if k in tenant.slot_map}
        key = (tenant.sig, tenant.payload_ids, buffers_signature(canon),
               tenant.kernel_mode)
        return _Request(tenant, buffers, canon, key, deadline=deadline)

    def submit(self, tenant_name: str, buffers: Mapping[str, Any],
               deadline: float | None = None) -> Future:
        """Enqueue one request; resolves to the region's output dict.

        ``deadline`` is an absolute ``time.monotonic()`` instant (or
        ``None`` for no deadline): a request still undispatched when it
        passes is shed (``DeadlineExceeded`` future, ``deadline_sheds``
        counter) instead of wasting a replay. Raises :class:`QueueFull`
        when the bounded admission queue is at capacity.
        """
        req = self._make_request(tenant_name, buffers, deadline=deadline)
        with self._cv:
            if self._closed:
                raise RuntimeError(f"server {self.name!r} is closed")
            if self.queue_bound and len(self._queue) >= self.queue_bound:
                self.metrics.on_shed()
                raise QueueFull(
                    f"server {self.name!r} admission queue is at its bound "
                    f"({self.queue_bound}); request shed")
            self._queue.append(req)
            req.tenant.requests += 1
            depth = len(self._queue)
            self._cv.notify_all()
        self.metrics.on_admit(depth)
        return req.future

    def submit_many(self, items: list[tuple]) -> list[Future]:
        """Admit a whole batch frame under ONE queue-lock acquisition.

        ``items`` entries are ``(tenant_name, buffers)`` or
        ``(tenant_name, buffers, deadline)`` (absolute monotonic, ``None``
        ok); the return list is positionally aligned with it. Per-entry
        validation failures (unknown tenant, missing input slots) come back
        as pre-failed futures — one bad entry in a wire batch must not
        reject its neighbours, and the cluster tier needs a per-entry error
        to route back to the right caller. Entries that do not fit under
        the queue bound come back pre-failed with :class:`QueueFull`; an
        entry whose deadline has *already* passed is shed at admission
        (pre-failed ``DeadlineExceeded``) without touching the queue.
        """
        results: list[Future] = []
        admitted: list[_Request] = []
        now = time.monotonic()
        n_expired = 0
        for item in items:
            tenant_name, buffers = item[0], item[1]
            deadline = item[2] if len(item) > 2 else None
            if deadline is not None and deadline <= now:
                fut: Future = Future()
                fut.set_exception(DeadlineExceeded(
                    f"deadline passed before admission for tenant "
                    f"{tenant_name!r}"))
                results.append(fut)
                n_expired += 1
                continue
            try:
                req = self._make_request(tenant_name, buffers,
                                         deadline=deadline)
            except Exception as exc:
                fut = Future()
                fut.set_exception(exc)
                results.append(fut)
                continue
            admitted.append(req)
            results.append(req.future)
        if n_expired:
            self.metrics.on_deadline_shed(n_expired)
        if admitted:
            overflow: list[_Request] = []
            with self._cv:
                if self._closed:
                    err = RuntimeError(f"server {self.name!r} is closed")
                    for req in admitted:
                        req.future.set_exception(err)
                    return results
                for i, req in enumerate(admitted):
                    if self.queue_bound and \
                            len(self._queue) >= self.queue_bound:
                        overflow = admitted[i:]
                        admitted = admitted[:i]
                        break
                    self._queue.append(req)
                    req.tenant.requests += 1
                depth = len(self._queue)
                self._cv.notify_all()
            for req in overflow:
                req.future.set_exception(QueueFull(
                    f"server {self.name!r} admission queue is at its bound "
                    f"({self.queue_bound}); request shed"))
            if overflow:
                self.metrics.on_shed(len(overflow))
            if admitted:
                self.metrics.on_admit_many(len(admitted), depth)
        return results

    def serve(self, tenant_name: str, buffers: Mapping[str, Any],
              timeout: float | None = 60.0) -> dict:
        """Synchronous :meth:`submit` — blocks for this request's result."""
        return self.submit(tenant_name, buffers).result(timeout=timeout)

    def stats(self) -> dict:
        """Serving metrics + pool counters + the global intern counters."""
        with self._cv:
            tenants = {t.name: t.requests for t in self._tenants.values()}
        return {
            "server": self.name,
            "max_batch": self.max_batch,
            "queue_bound": self.queue_bound,
            "tenants": tenants,
            "metrics": self.metrics.snapshot(),
            "pool": self.pool.stats(),
            "intern": _lower.intern_stats(),
        }

    # ------------------------------------------------------------- dispatch
    def _take_matching(self, group: list[_Request], key: tuple) -> None:
        """Move queued requests with ``key`` into ``group`` (up to max_batch)."""
        kept: collections.deque[_Request] = collections.deque()
        while self._queue:
            r = self._queue.popleft()
            if r.key == key and len(group) < self.max_batch:
                group.append(r)
            else:
                kept.append(r)
        self._queue.extend(kept)

    def _dispatch_loop(self) -> None:
        while True:
            with self._cv:
                while not self._queue and not self._closed:
                    self._cv.wait()
                if not self._queue:     # closed and drained
                    return
                head = self._queue.popleft()
                group = [head]
                if self.max_batch > 1:
                    deadline = time.monotonic() + self.max_wait_s
                    while len(group) < self.max_batch:
                        self._take_matching(group, head.key)
                        if len(group) >= self.max_batch or self._closed:
                            break
                        if self._queue:
                            # Everything still queued is non-matching (all
                            # matches were just taken): holding the window
                            # open would head-of-line block other keys for
                            # up to max_wait for companions that may never
                            # come. Dispatch now; stragglers form the next
                            # group.
                            break
                        remaining = deadline - time.monotonic()
                        if remaining <= 0:
                            break
                        self._cv.wait(remaining)
                    self._take_matching(group, head.key)
            self._execute_group(group)

    # ------------------------------------------------------------- execution
    def _execute_group(self, group: list[_Request]) -> None:
        # Shed members whose deadline already passed BEFORE spending a
        # replay on them: the submitter stopped waiting, so the only thing
        # executing buys is wasted compute in front of live requests.
        now = time.monotonic()
        expired = [r for r in group if r.deadline is not None
                   and r.deadline <= now]
        if expired:
            group = [r for r in group if r not in expired]
            self.metrics.on_deadline_shed(len(expired))
            for r in expired:
                self.metrics.on_done(now - r.t_submit, failed=True)
                r.future.set_exception(DeadlineExceeded(
                    f"deadline passed while queued for tenant "
                    f"{r.tenant.name!r}"))
            if not group:
                return
        coalesced = False
        try:
            if len(group) == 1:
                # A lone request (no coalescing partner inside the window)
                # takes the interned single-request path — never a K=1
                # specialization of the batched program.
                results = [self._run_single(group[0])]
            else:
                results, coalesced = self._run_batched(group)
            jax.block_until_ready(results)
        except Exception as exc:
            now = time.monotonic()
            for r in group:
                self.metrics.on_done(now - r.t_submit, failed=True)
                r.future.set_exception(exc)
            return
        self.metrics.on_batch(len(group), coalesced=coalesced)
        now = time.monotonic()
        for r, out in zip(group, results):
            if isinstance(out, Exception):      # per-request fallback failure
                self.metrics.on_done(now - r.t_submit, failed=True)
                r.future.set_exception(out)
            else:
                self.metrics.on_done(now - r.t_submit, aot=r.served_aot)
                r.future.set_result(out)

    def _run_single(self, req: _Request) -> dict:
        tenant = req.tenant
        aot = self._aot_for(req)
        if aot is not None:
            req.served_aot = True
            with _kreg.kernel_mode_scope(tenant.kernel_mode):
                return aot(req.buffers)
        fn = tenant.replay_fn()
        with _kreg.kernel_mode_scope(tenant.kernel_mode):
            return fn(dict(req.buffers))

    def _aot_for(self, req: _Request) -> "_lower.AotExecutable | None":
        """The tenant's warm AOT executable, iff shapes match this request.

        Pool-evicted AOT entries are re-hydrated from the tenant's
        ``warm_path`` sidecar when possible (cold tenants pay a disk read,
        not a retrace); irrecoverable sidecars permanently fall back to the
        interned lazy path.
        """
        tenant = req.tenant
        if tenant.aot_key is None:
            return None
        want = buffers_signature(
            {k: v for k, v in req.buffers.items()
             if k in self._aot_spec_slots(tenant)})
        if want != tenant.aot_sig:
            return None
        entry = self.pool.get(tenant.aot_key)
        if entry is not None:
            return entry.fn
        if tenant.warm_path is not None:
            try:
                aot = _serialize.load_executable(str(tenant.warm_path) + ".aot")
            except Exception:
                tenant.aot_key = None       # unrecoverable: stop retrying
                self.metrics.on_aot_hydrate_failure()
                return None
            self._install_aot(tenant, aot, hydrated=True)
            return aot
        tenant.aot_key = None
        return None

    def _aot_spec_slots(self, tenant: Tenant) -> tuple:
        # aot_sig rows are (slot, treedef, leafspec): recover the slot set.
        return tuple(row[0] for row in (tenant.aot_sig or ()))

    def _run_batched(self, group: list[_Request]) -> tuple[list, bool]:
        """Serve a coalesced group; returns ``(results, coalesced)``.

        ``coalesced`` is True only when ONE fused vmap-batched call served
        the whole group, so the metrics never report fallback groups as
        real cross-request fusion.
        """
        try:
            return self._run_batched_fused(group), True
        except Exception:
            # A payload without a batching rule (or any trace-time failure
            # specific to the vmapped form) degrades THIS batch to serial
            # per-request replay; single-request bugs still surface from
            # _run_single with their real error — per request, so one
            # member's failure cannot poison its siblings' results.
            self.metrics.on_batch_fallback()
            results: list[dict | Exception] = []
            for r in group:
                try:
                    results.append(self._run_single(r))
                except Exception as exc:
                    results.append(exc)
            return results, False

    def _run_batched_fused(self, group: list[_Request]) -> list[dict]:
        tenant0 = group[0].tenant
        canon = [r.canon_buffers for r in group]
        slots = sorted(canon[0])
        shared = frozenset(
            s for s in slots
            if all(cb[s] is canon[0][s] for cb in canon[1:]))
        varying = tuple(s for s in slots if s not in shared)
        shared_bufs = {s: canon[0][s] for s in shared}
        if not varying:
            # Every buffer is literally shared: one single-request replay
            # serves the whole batch (all members compute the same values).
            out0 = self._run_single(group[0])
            canon_out = {group[0].tenant.slot_map[s]: v
                         for s, v in out0.items()}
            return [{r.tenant.from_canon[c]: v for c, v in canon_out.items()}
                    for r in group]
        key = ("batched", tenant0.sig, tenant0.payload_ids, shared,
               tenant0.kernel_mode)
        entry = self.pool.get(key)
        if entry is None:
            entry = self.pool.put(key, PoolEntry(
                "batched", self._build_batched(tenant0), tenant0.payloads))
        # Bucket occupancy to the next power of two (padding with a repeat
        # of the last member, dropped after the call): jit specializes the
        # batched program per pytree arity, so without bucketing every
        # straggler-induced occupancy K would pay a fresh trace+compile.
        # Buckets bound that to log2(max_batch) compilations total.
        per_req = [{s: cb[s] for s in varying} for cb in canon]
        bucket = 2
        while bucket < len(per_req):
            bucket *= 2
        per_req.extend(per_req[-1:] * (bucket - len(per_req)))
        with _kreg.kernel_mode_scope(tenant0.kernel_mode):
            outs = entry.fn(tuple(per_req), shared_bufs)
        return [{r.tenant.from_canon[c]: v for c, v in out_j.items()}
                for r, out_j in zip(group, outs)]

    def _build_batched(self, tenant: Tenant) -> Callable[..., tuple]:
        """One jitted cross-request batch callable on canonical slot names.

        ``fn(per_request, shared) -> tuple[dict, ...]`` where
        ``per_request`` is a tuple of per-member buffer dicts. Stacking the
        request axis, ``vmap``-ing the canonical region function over it,
        and re-slicing the outputs per member ALL happen inside the one
        jitted program — a whole batch costs a single dispatch, which is
        where coalescing beats serial replay. Shared buffers enter as
        unbatched jit arguments closed over inside the vmap body, i.e.
        broadcast — the cross-request analogue of ``WaveClass.shared``
        argument handling. Occupancy is a pytree shape, so one callable
        serves every batch size via jit's per-structure specialization.
        """
        with _kreg.kernel_mode_scope(tenant.kernel_mode):
            base = _lower.lower_tdg(
                tenant.tdg, jit=False, fuse=self.fuse,
                outputs=list(tenant.outputs)
                if tenant.outputs is not None else None)
        from_canon = tenant.from_canon
        slot_map = tenant.slot_map

        def canon_base(cbufs: dict) -> dict:
            out = base({from_canon[c]: v for c, v in cbufs.items()})
            return {slot_map[s]: v for s, v in out.items()}

        def batched(per_req: tuple, shared_bufs: dict) -> tuple:
            stacked = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs, axis=0), *per_req)

            def one(st: dict) -> dict:
                return canon_base({**st, **shared_bufs})

            out = jax.vmap(one)(stacked)
            return tuple(
                jax.tree_util.tree_map(lambda v, _j=j: v[_j], out)
                for j in range(len(per_req)))

        batched.__name__ = f"tdg_batched_{tenant.tdg.region}"
        return jax.jit(batched)
