"""Multi-tenant taskgraph region server (the serving tier over replay).

The record-and-replay model exists so a region is orchestrated once and
replayed with near-zero management overhead; this module is the step from
"replay one region fast" to "serve many tenants fast". Following the
async-manager shape of Bosch et al. (arXiv:2009.03066) — clients enqueue
work, one manager thread owns dispatch — a :class:`RegionServer` accepts
requests against registered *tenants* (a named TDG + pinned kernel mode)
through an **admission queue** and serves them from shared compiled
executables:

* **Coalescing.** Concurrent requests whose TDGs canonicalize to the same
  ``tdg.structure_signature`` (and same payload identities, buffer shapes
  and kernel mode) are batched into ONE fused replay: buffers are stacked
  along a fresh leading axis and the canonical region function is
  ``vmap``-ed across *requests* — the same trick ``fuse._run_fused_class``
  plays across wave-mates, lifted across tenants. Buffers that are the
  *same object* in every member request (e.g. shared model params) are
  broadcast, not stacked. A batch whose payloads refuse to vmap falls back
  to per-request replay for that batch only.
* **Warm pool.** Batched callables live in an LRU-bounded
  :class:`~repro.serving.pool.WarmPool` keyed by structure + payload
  identities + kernel mode — never by tenant name — so N structurally
  identical tenants share one entry; AOT executables live there too,
  keyed per tenant (their compiled input specs name that tenant's
  slots/shapes). Single-request replay goes through
  ``lower.lower_tdg``'s global structural intern cache, so tenant
  #2..#N reuse tenant #1's jitted executable (``intern_stats()`` counts
  the hits). Cold tenants registered with a ``warm_path`` hydrate their
  compiled binary from the ``.aot`` sidecar (``serialize.load_warm``)
  instead of retracing.
* **Isolation.** Payload identities partition the coalescing key: two
  tenants with same-shaped graphs over *different* payload closures never
  share an executable or a batch. Each tenant's kernel substrate is
  resolved once at registration and re-entered as a
  ``kernel_mode_scope`` around every lowering and call (exactly
  ``ReplayExecutor``'s pinning), so a global ``REPRO_KERNELS`` flip cannot
  change what an already-registered tenant executes.
* **Continuous (iteration-level) batching.** The default scheduler is no
  longer run-to-completion: each structure class owns a *resident batch*
  that tenants join and leave **between** fused replay steps. New requests
  are admitted at step boundaries into the existing power-of-two occupancy
  buckets, finished sequences retire without draining their batch-mates,
  and membership churn re-slices the same pooled/interned executables —
  it never retraces. Multi-step decode work rides :meth:`RegionServer.
  submit_stream`: the member stays resident across steps, each step's
  outputs overwriting its same-named input slots (the repo's standard
  decode-carry idiom), so a K-step stream costs K fused steps and zero
  per-step client round-trips. ``continuous=False`` (or
  ``REPRO_CONTINUOUS=0``) restores the PR-6 run-to-completion dispatcher
  — kept as the benchmark baseline and kill switch.
* **QoS admission.** Per-tenant token buckets (:class:`~repro.serving.
  qos.TokenBucket`; ``rate=`` at registration or ``REPRO_TENANT_RATE``)
  refuse over-rate submissions with typed :class:`RateLimited`; priority
  tiers (``tier=`` / ``REPRO_TENANT_TIER``) drive smooth weighted
  round-robin admission at step boundaries (weight ``2**tier``) and
  compose with PR 7's bounded queue so **low-tier work sheds first**: at
  a full queue a higher-tier arrival evicts the newest lowest-tier waiter
  (its future fails ``QueueFull``) instead of being refused itself.
* **Metrics.** Queue depth, batch occupancy, pool hit rate, p50/p99
  replay latency — now per tier — plus a per-step execution-pattern
  trace ring (:class:`~repro.serving.metrics.ExecutionTraceRing`,
  :meth:`RegionServer.dump_trace`) — see :mod:`repro.serving.metrics`.
"""
from __future__ import annotations

import collections
import dataclasses
import os
import threading
import time
from concurrent.futures import Future
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp

from ..core import costmodel as _costmodel
from ..core import lower as _lower
from ..core import serialize as _serialize
from ..core.tdg import TDG, buffers_signature, structure_signature
from ..kernels import registry as _kreg
from ..sharding import replay as _shreplay
from .metrics import ServerMetrics
from .pool import PoolEntry, WarmPool
from .qos import SmoothWRR, TokenBucket, tenant_rate_default, \
    tenant_tier_default, tier_weight

#: Admission-queue bound (requests). ``0`` / unset = unbounded (the
#: pre-backpressure behaviour). When the queue is at the bound, new
#: submissions are refused with :class:`QueueFull` instead of growing the
#: queue without limit under overload.
QUEUE_BOUND_ENV = "REPRO_QUEUE_BOUND"

#: Scheduler selector. Unset/``1`` = iteration-level (continuous)
#: batching; ``0``/``false``/``off`` = the PR-6 run-to-completion
#: dispatcher (benchmark baseline / kill switch).
CONTINUOUS_ENV = "REPRO_CONTINUOUS"


class QueueFull(RuntimeError):
    """Admission refused: the server's bounded queue is at capacity.

    This is the load-shedding signal — the submitter should back off or
    route elsewhere. Deliberately a *typed* error so the cluster frontend
    can tell backpressure (don't retry the same worker immediately) from a
    worker fault (retry a sibling)."""


class DeadlineExceeded(RuntimeError):
    """The request's deadline passed before a result could be produced.

    Raised into the request future either at admission/dispatch time (the
    request was shed unexecuted — see ``deadline_sheds``) or by the cluster
    frontend's deadline sweep when a reply never arrived. Terminal: the
    retry machinery never retries past a deadline."""


class RateLimited(RuntimeError):
    """Admission refused: the tenant's token bucket is dry.

    Per-tenant backpressure, distinct from the server-wide
    :class:`QueueFull`: THIS tenant exceeded its configured rate
    (``register_tenant(rate=...)`` / ``REPRO_TENANT_RATE``) — its
    neighbours are unaffected. Typed so it crosses the cluster RPC wire
    by name (like ``QueueFull``/``DeadlineExceeded``) and is terminal:
    retrying a rate-limited request on a sibling would defeat the limit.
    """


def queue_bound_default() -> int:
    """The env-configured admission bound (0 = unbounded)."""
    raw = os.environ.get(QUEUE_BOUND_ENV, "").strip()
    return max(0, int(raw)) if raw else 0


def continuous_default() -> bool:
    """Env-configured scheduler choice (default: continuous batching on)."""
    raw = os.environ.get(CONTINUOUS_ENV, "").strip().lower()
    return raw not in ("0", "false", "off", "no")


@dataclasses.dataclass
class Tenant:
    """One registered tenant: a region (TDG) plus its pinned substrate.

    ``sig``/``slot_map``/``payloads`` are the canonical structure computed
    once at registration; ``kernel_mode`` is the *resolved* substrate
    (never ``"auto"``), chosen at registration exactly like
    ``ReplayExecutor`` pins it at construction. ``tier`` is the QoS
    priority (higher = more admission weight at step boundaries, sheds
    last under pressure); ``rate`` > 0 arms a per-tenant token bucket.
    """

    name: str
    tdg: TDG
    outputs: tuple[str, ...] | None
    kernel_mode: str
    sig: tuple
    slot_map: dict[str, str]
    payloads: tuple
    warm_path: str | None = None
    fuse: bool | str = "auto"
    #: The server's resolved replay mesh (a concrete Mesh or None), pinned
    #: at registration — every lowering for this tenant shards under it.
    mesh: Any = None
    aot_key: tuple | None = None
    aot_sig: tuple | None = None
    requests: int = 0
    tier: int = 0
    rate: float = 0.0

    def __post_init__(self) -> None:
        self.payload_ids = tuple(id(p) for p in self.payloads)
        self.from_canon = {c: a for a, c in self.slot_map.items()}
        self.input_slots = tuple(s for s in self.tdg.input_slots
                                 if s in self.slot_map)
        self.bucket = TokenBucket(self.rate) if self.rate > 0 else None
        self._fn: Callable[[dict], dict] | None = None
        self._fn_lock = threading.Lock()

    def replay_fn(self) -> Callable[[dict], dict]:
        """The (lazily built) single-request replay callable.

        Built via ``lower.lower_tdg`` under this tenant's pinned mode, so
        it lands in — or is served from — the global structural intern
        cache shared with every other structurally identical tenant.
        """
        with self._fn_lock:
            if self._fn is None:
                with _kreg.kernel_mode_scope(self.kernel_mode):
                    self._fn = _lower.lower_tdg(
                        self.tdg, fuse=self.fuse, mesh=self.mesh,
                        outputs=list(self.outputs)
                        if self.outputs is not None else None)
            return self._fn


class _Request:
    """One admitted unit of work — and, continuously, one batch *member*.

    Under the continuous scheduler a request with ``steps > 1`` is a
    resident stream: it stays in its class's batch across steps, each
    step's outputs overwriting its same-named input slots, and its future
    resolves with the FINAL step's outputs.
    """

    __slots__ = ("tenant", "buffers", "canon_buffers", "key", "future",
                 "t_submit", "served_aot", "deadline", "steps", "steps_done")

    def __init__(self, tenant: Tenant, buffers: dict, canon_buffers: dict,
                 key: tuple, deadline: float | None = None, steps: int = 1):
        self.tenant = tenant
        self.buffers = buffers
        self.canon_buffers = canon_buffers
        self.key = key
        self.future: Future = Future()
        self.t_submit = time.monotonic()
        self.served_aot = False
        self.deadline = deadline       # absolute time.monotonic(), or None
        self.steps = steps
        self.steps_done = 0


class _ClassState:
    """Continuous-scheduler state for one coalescing key (structure class).

    ``resident`` is the live batch stepped as one fused replay;
    ``pending`` holds admitted-but-not-yet-joined members, drained into
    ``resident`` at step boundaries by tier-weighted round robin.
    """

    __slots__ = ("key", "cid", "resident", "pending", "step", "wrr")

    def __init__(self, key: tuple, cid: int):
        self.key = key
        self.cid = cid
        self.resident: list[_Request] = []
        self.pending: list[_Request] = []
        self.step = 0
        self.wrr = SmoothWRR()         # tier selector for admission slots

    def busy(self) -> bool:
        return bool(self.resident or self.pending)


class RegionServer:
    """Admission-queued, batch-coalescing server over interned replay.

    Parameters
    ----------
    max_batch:
        Coalescing ceiling — how many structurally identical requests one
        fused replay may carry. ``1`` disables batching (serial
        per-request replay; the benchmark baseline).
    max_wait_ms:
        Admission window: after the first request of a batch arrives, how
        long the dispatcher waits for same-structure companions before
        dispatching a partial batch. Bounded head-of-line latency.
    pool_capacity:
        LRU bound on the warm-executable pool.
    queue_bound:
        Admission-queue bound (requests). ``None`` honours
        ``REPRO_QUEUE_BOUND``; ``0`` means unbounded. At the bound, new
        submissions are refused with :class:`QueueFull` (counted in the
        ``shed`` metric) instead of growing the queue under overload.
    fuse:
        Wave-fusion policy handed to every lowering this server performs
        (single-request AND batched paths): ``True`` / ``False`` /
        ``"auto"`` (honour ``REPRO_FUSE``), as in ``lower.lower_tdg``.
    autostart:
        Start the scheduler thread immediately. Tests pass ``False``,
        enqueue a known set of requests, then call :meth:`start` for a
        deterministic first batch / first step-boundary admission.
    continuous:
        ``True`` = iteration-level batching (resident per-class batches,
        step-boundary joins/leaves, streams); ``False`` = the PR-6
        run-to-completion dispatcher. ``None`` honours
        ``REPRO_CONTINUOUS`` (default: continuous).
    """

    def __init__(self, max_batch: int = 8, max_wait_ms: float = 2.0,
                 pool_capacity: int = 64, fuse: bool | str = "auto",
                 name: str = "region-server", autostart: bool = True,
                 queue_bound: int | None = None,
                 continuous: bool | None = None,
                 adaptive: bool | str = "auto",
                 mesh: Any = "auto"):
        self.name = name
        self.max_batch = max(1, int(max_batch))
        self.max_wait_s = max(0.0, float(max_wait_ms)) / 1e3
        self.queue_bound = (queue_bound_default() if queue_bound is None
                            else max(0, int(queue_bound)))
        self.continuous = (continuous_default() if continuous is None
                           else bool(continuous))
        self.fuse = fuse
        # Adaptive occupancy buckets ("auto" honours REPRO_ADAPTIVE): the
        # tuner starts on the pow-2 ladder and refits boundaries from the
        # live occupancy histogram under a bounded retrace budget; a refit
        # invalidates the pool's stale batched executables. adaptive=False
        # (or REPRO_ADAPTIVE=0) pins the static pow-2 ladder for good.
        self.adaptive = _costmodel.adaptive_enabled(adaptive)
        self.buckets = _costmodel.BucketTuner(self.max_batch,
                                              adaptive=self.adaptive)
        # Resolved ONCE at construction (like each tenant's kernel mode):
        # every lowering this server performs — single-request, batched,
        # warmup AOT — shards the coalesced batch axis under this mesh, and
        # its fingerprint partitions the WarmPool keys so 1-device and
        # N-device executables never collide. "auto" honours an ambient
        # use_mesh scope, then REPRO_MESH (sharding.replay.resolve_mesh).
        self.mesh = _shreplay.resolve_mesh(mesh)
        self.mesh_fp = _shreplay.mesh_fingerprint(self.mesh)
        self.pool = WarmPool(capacity=pool_capacity)
        self.metrics = ServerMetrics()
        self._tenants: dict[str, Tenant] = {}
        self._queue: collections.deque[_Request] = collections.deque()
        self._cv = threading.Condition()
        self._closed = False
        self._started = False
        # Continuous-scheduler state (unused by the legacy dispatcher).
        self._classes: dict[tuple, _ClassState] = {}
        self._next_cid = 0
        self._pending_count = 0        # members parked in class pendings
        self._class_wrr = SmoothWRR()  # which class steps next
        self._thread = threading.Thread(
            target=(self._scheduler_loop if self.continuous
                    else self._dispatch_loop),
            name=f"{name}-dispatch", daemon=True)
        if autostart:
            self.start()

    # ------------------------------------------------------------ lifecycle
    def start(self) -> None:
        """Start the dispatcher thread (idempotent)."""
        if not self._started:
            self._started = True
            self._thread.start()

    def close(self) -> None:
        """Drain the admission queue, then stop the dispatcher.

        Holds even for a never-started server (``autostart=False``) with
        requests already queued: the dispatcher is started just to drain
        them, so no pending future is ever silently abandoned.
        """
        with self._cv:
            self._closed = True
            self._cv.notify_all()
            pending = bool(self._queue) or self._pending_count > 0
        if not self._started and pending:
            self.start()
        if self._started:
            self._thread.join()

    def __enter__(self) -> "RegionServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------- tenants
    def register_tenant(self, name: str, tdg: TDG | None = None, *,
                        outputs: tuple[str, ...] | None = None,
                        kernel_mode: str | None = None,
                        warm_path: str | None = None,
                        fn_registry: "_serialize.TaskFnRegistry | None" = None,
                        tier: int | None = None,
                        rate: float | None = None,
                        ) -> Tenant:
        """Register a tenant by TDG, or hydrate one from a warm artifact.

        Exactly one of ``tdg`` / ``warm_path`` selects the region source:
        ``warm_path`` names a TDG JSON written by
        ``serialize.warmup_and_save`` (payloads re-linked through
        ``fn_registry``); if its ``.aot`` sidecar is present and loadable,
        the compiled binary is installed in the warm pool so this tenant's
        first request replays without any retrace. A missing or corrupt
        sidecar degrades silently to the ordinary (interned, lazily
        traced) replay path — hydration is an optimization, never a
        correctness dependency.

        ``tier`` (QoS priority; higher wins contended admission slots and
        sheds last) and ``rate`` (sustained req/s through a token bucket;
        0 = unlimited) default to the per-tenant ``REPRO_TENANT_TIER`` /
        ``REPRO_TENANT_RATE`` environment specs.
        """
        if (tdg is None) == (warm_path is None):
            raise ValueError("pass exactly one of tdg= or warm_path=")
        aot = None
        sidecar_present = False
        if warm_path is not None:
            if fn_registry is None:
                raise ValueError("warm_path= requires fn_registry= to "
                                 "re-link task payloads")
            sidecar_present = os.path.exists(str(warm_path) + ".aot")
            tdg, aot = _serialize.load_warm(warm_path, fn_registry,
                                            mesh=self.mesh_fp)
        tdg.validate()
        mode = _kreg.resolved_mode(kernel_mode)
        sig, slot_map, payloads = structure_signature(
            tdg, list(outputs) if outputs is not None else None)
        tenant = Tenant(name=name, tdg=tdg,
                        outputs=tuple(outputs) if outputs is not None else None,
                        kernel_mode=mode, sig=sig, slot_map=slot_map,
                        payloads=payloads, warm_path=warm_path,
                        fuse=self.fuse, mesh=self.mesh,
                        tier=(tenant_tier_default(name) if tier is None
                              else max(0, int(tier))),
                        rate=(tenant_rate_default(name) if rate is None
                              else max(0.0, float(rate))))
        with self._cv:
            if name in self._tenants:
                raise ValueError(f"tenant {name!r} already registered")
            self._tenants[name] = tenant
        if aot is not None:
            self._install_aot(tenant, aot, hydrated=True)
        elif sidecar_present:
            # The sidecar was on disk but load_warm soft-fell back (corrupt,
            # truncated, platform/version mismatch, or a jax build without
            # executable serialization). The tenant still works — lazily
            # traced — but it is NOT warm, and pretending otherwise is how
            # cold-start regressions hide. Make the fallback loud in metrics.
            self.metrics.on_aot_hydrate_failure()
        return tenant

    def tenant(self, name: str) -> Tenant:
        with self._cv:
            if name not in self._tenants:
                raise KeyError(f"unknown tenant {name!r}; registered: "
                               f"{sorted(self._tenants)}")
            return self._tenants[name]

    def warmup(self, name: str, buffers: Mapping[str, Any]) -> dict:
        """Eagerly AOT-compile a tenant's replay executable into the pool.

        ``buffers`` may be concrete arrays or ``ShapeDtypeStruct`` specs.
        Returns the compile report (cost analysis, trace/compile seconds)
        so callers can budget warmup off the serving critical path.
        """
        tenant = self.tenant(name)
        with _kreg.kernel_mode_scope(tenant.kernel_mode):
            aot = _lower.aot_compile_tdg(
                tenant.tdg, buffers, fuse=tenant.fuse, mesh=tenant.mesh,
                outputs=list(tenant.outputs)
                if tenant.outputs is not None else None)
        self._install_aot(tenant, aot)
        return {"tenant": name, "fused": aot.fused,
                "cost_analysis": aot.cost_analysis,
                "trace_seconds": aot.trace_seconds,
                "compile_seconds": aot.compile_seconds}

    def install_aot(self, name: str, aot: "_lower.AotExecutable",
                    hydrated: bool = False) -> None:
        """Install an externally produced AOT executable for tenant ``name``.

        This is how the cluster tier's :class:`~repro.serving.cluster.
        WorkerNode` plants an executable hydrated from *shipped* artifact
        bytes (``serialize.executable_from_bytes``) — the worker never
        re-lowers what the frontend already compiled. ``hydrated=True``
        counts it in the pool's hydration counter.
        """
        self._install_aot(self.tenant(name), aot, hydrated=hydrated)

    def _install_aot(self, tenant: Tenant, aot: "_lower.AotExecutable",
                     hydrated: bool = False) -> None:
        aot_sig = buffers_signature(aot.input_specs)
        key = ("aot", tenant.name, aot_sig, tenant.kernel_mode, self.mesh_fp)
        self.pool.put(key, PoolEntry("aot", aot, tenant.payloads),
                      hydrated=hydrated)
        tenant.aot_key = key
        tenant.aot_sig = aot_sig

    # ------------------------------------------------------------ admission
    def _make_request(self, tenant_name: str, buffers: Mapping[str, Any],
                      deadline: float | None = None,
                      steps: int = 1) -> "_Request":
        """Validate + canonicalize one submission into a queue entry."""
        tenant = self.tenant(tenant_name)
        missing = [s for s in tenant.input_slots if s not in buffers]
        if missing:
            raise KeyError(f"request for tenant {tenant_name!r} is missing "
                           f"input slots {missing}")
        buffers = dict(buffers)
        canon = {tenant.slot_map[k]: v for k, v in buffers.items()
                 if k in tenant.slot_map}
        key = (tenant.sig, tenant.payload_ids, buffers_signature(canon),
               tenant.kernel_mode)
        return _Request(tenant, buffers, canon, key, deadline=deadline,
                        steps=steps)

    def _waiting_locked(self) -> int:
        """Admitted-but-not-resident requests: the bounded-queue population.

        Under the continuous scheduler, waiting work lives both in the
        raw admission queue and in per-class pending lists (parked for a
        step boundary) — the queue bound must count both or draining into
        pendings would quietly disable backpressure.
        """
        return len(self._queue) + self._pending_count

    def _evict_lower_tier_locked(self, tier: int) -> "_Request | None":
        """Pop the newest waiting request of the lowest tier below ``tier``.

        The low-tier-sheds-first half of tier QoS: at a full queue a
        higher-tier arrival displaces best-effort work instead of being
        refused. Newest-first within the victim tier, so the longest-
        waiting low-tier request keeps its FIFO claim on the next slot.
        """
        victim_tier = tier
        place: tuple | None = None
        for i in range(len(self._queue) - 1, -1, -1):
            if self._queue[i].tenant.tier < victim_tier:
                victim_tier = self._queue[i].tenant.tier
                place = (None, i)
        for cls in self._classes.values():
            for i in range(len(cls.pending) - 1, -1, -1):
                if cls.pending[i].tenant.tier < victim_tier:
                    victim_tier = cls.pending[i].tenant.tier
                    place = (cls, i)
        if place is None:
            return None
        cls, i = place
        if cls is None:
            victim = self._queue[i]
            del self._queue[i]
        else:
            victim = cls.pending.pop(i)
            self._pending_count -= 1
        return victim

    def _admit(self, req: "_Request") -> tuple[int, "_Request | None"]:
        """Admission control for one request: closed / rate / bound checks.

        Returns ``(queue depth, evicted victim or None)``; raises
        :class:`RateLimited` / :class:`QueueFull`. The victim's future is
        failed by the caller OUTSIDE the lock.
        """
        tenant = req.tenant
        with self._cv:
            if self._closed:
                raise RuntimeError(f"server {self.name!r} is closed")
            if tenant.bucket is not None and not tenant.bucket.take():
                self.metrics.on_rate_limited()
                raise RateLimited(
                    f"tenant {tenant.name!r} exceeded its rate limit "
                    f"({tenant.rate:g} req/s); request refused")
            victim = None
            if self.queue_bound and self._waiting_locked() >= self.queue_bound:
                victim = self._evict_lower_tier_locked(tenant.tier)
                if victim is None:
                    self.metrics.on_shed()
                    raise QueueFull(
                        f"server {self.name!r} admission queue is at its "
                        f"bound ({self.queue_bound}); request shed")
            self._queue.append(req)
            tenant.requests += 1
            depth = self._waiting_locked()
            self._cv.notify_all()
        if victim is not None:
            self.metrics.on_shed()
            victim.future.set_exception(QueueFull(
                f"server {self.name!r} admission queue is at its bound "
                f"({self.queue_bound}); shed for a tier-{tenant.tier} "
                f"arrival"))
        return depth, victim

    def submit(self, tenant_name: str, buffers: Mapping[str, Any],
               deadline: float | None = None) -> Future:
        """Enqueue one request; resolves to the region's output dict.

        ``deadline`` is an absolute ``time.monotonic()`` instant (or
        ``None`` for no deadline): a request still undispatched when it
        passes is shed (``DeadlineExceeded`` future, ``deadline_sheds``
        counter) instead of wasting a replay. Raises :class:`QueueFull`
        when the bounded admission queue is at capacity (unless a
        lower-tier waiter can be shed instead) and :class:`RateLimited`
        when the tenant's token bucket is dry.
        """
        req = self._make_request(tenant_name, buffers, deadline=deadline)
        depth, _ = self._admit(req)
        self.metrics.on_admit(depth)
        return req.future

    def submit_stream(self, tenant_name: str, buffers: Mapping[str, Any],
                      steps: int, deadline: float | None = None) -> Future:
        """Enqueue a ``steps``-step resident stream (continuous mode only).

        The member joins its structure class's resident batch at a step
        boundary and stays for ``steps`` fused replay steps; between
        steps, outputs overwrite same-named input slots (the decode-carry
        idiom — ``bufs.update(out)``), all server-side, with no per-step
        client round-trip. The future resolves with the FINAL step's
        outputs. Joining and leaving never retraces: membership churn
        re-slices the same pooled occupancy-bucketed executables.
        """
        if not self.continuous:
            raise RuntimeError(
                "submit_stream requires continuous batching "
                "(RegionServer(continuous=True) / REPRO_CONTINUOUS=1)")
        if int(steps) < 1:
            raise ValueError(f"steps must be >= 1, got {steps}")
        req = self._make_request(tenant_name, buffers, deadline=deadline,
                                 steps=int(steps))
        depth, _ = self._admit(req)
        self.metrics.on_admit(depth)
        return req.future

    def submit_many(self, items: list[tuple]) -> list[Future]:
        """Admit a whole batch frame under ONE queue-lock acquisition.

        ``items`` entries are ``(tenant_name, buffers)`` or
        ``(tenant_name, buffers, deadline)`` (absolute monotonic, ``None``
        ok); the return list is positionally aligned with it. Per-entry
        validation failures (unknown tenant, missing input slots) come back
        as pre-failed futures — one bad entry in a wire batch must not
        reject its neighbours, and the cluster tier needs a per-entry error
        to route back to the right caller. Entries that do not fit under
        the queue bound come back pre-failed with :class:`QueueFull`; an
        entry whose deadline has *already* passed is shed at admission
        (pre-failed ``DeadlineExceeded``) without touching the queue.
        """
        results: list[Future] = []
        admitted: list[_Request] = []
        now = time.monotonic()
        n_expired = 0
        for item in items:
            tenant_name, buffers = item[0], item[1]
            deadline = item[2] if len(item) > 2 else None
            if deadline is not None and deadline <= now:
                fut: Future = Future()
                fut.set_exception(DeadlineExceeded(
                    f"deadline passed before admission for tenant "
                    f"{tenant_name!r}"))
                results.append(fut)
                n_expired += 1
                continue
            try:
                req = self._make_request(tenant_name, buffers,
                                         deadline=deadline)
            except Exception as exc:
                fut = Future()
                fut.set_exception(exc)
                results.append(fut)
                continue
            admitted.append(req)
            results.append(req.future)
        if n_expired:
            self.metrics.on_deadline_shed(n_expired)
        if admitted:
            overflow: list[_Request] = []
            limited: list[_Request] = []
            victims: list[_Request] = []
            n_in = 0
            with self._cv:
                if self._closed:
                    err = RuntimeError(f"server {self.name!r} is closed")
                    for req in admitted:
                        req.future.set_exception(err)
                    return results
                for req in admitted:
                    tenant = req.tenant
                    if tenant.bucket is not None and not tenant.bucket.take():
                        limited.append(req)
                        continue
                    if self.queue_bound and \
                            self._waiting_locked() >= self.queue_bound:
                        victim = self._evict_lower_tier_locked(tenant.tier)
                        if victim is None:
                            overflow.append(req)
                            continue
                        victims.append(victim)
                    self._queue.append(req)
                    tenant.requests += 1
                    n_in += 1
                depth = self._waiting_locked()
                self._cv.notify_all()
            for req in limited:
                req.future.set_exception(RateLimited(
                    f"tenant {req.tenant.name!r} exceeded its rate limit "
                    f"({req.tenant.rate:g} req/s); request refused"))
            if limited:
                self.metrics.on_rate_limited(len(limited))
            for req in overflow + victims:
                req.future.set_exception(QueueFull(
                    f"server {self.name!r} admission queue is at its bound "
                    f"({self.queue_bound}); request shed"))
            if overflow or victims:
                self.metrics.on_shed(len(overflow) + len(victims))
            if n_in:
                self.metrics.on_admit_many(n_in, depth)
        return results

    def serve(self, tenant_name: str, buffers: Mapping[str, Any],
              timeout: float | None = 60.0) -> dict:
        """Synchronous :meth:`submit` — blocks for this request's result."""
        return self.submit(tenant_name, buffers).result(timeout=timeout)

    def stats(self) -> dict:
        """Serving metrics + pool counters + the global intern counters."""
        with self._cv:
            tenants = {t.name: t.requests for t in self._tenants.values()}
        return {
            "server": self.name,
            "max_batch": self.max_batch,
            "queue_bound": self.queue_bound,
            "continuous": self.continuous,
            "adaptive": self.adaptive,
            "mesh": self.mesh_fp,
            "tenants": tenants,
            "metrics": self.metrics.snapshot(),
            "pool": self.pool.stats(),
            "buckets": self.buckets.summary(),
            "intern": _lower.intern_stats(),
        }

    def dump_trace(self, path: str) -> dict:
        """Write the execution-pattern trace ring to ``path`` as JSON."""
        return self.metrics.trace.dump(path, meta={"server": self.name})

    # ------------------------------------------------------------- dispatch
    def _take_matching(self, group: list[_Request], key: tuple) -> None:
        """Move queued requests with ``key`` into ``group`` (up to max_batch)."""
        kept: collections.deque[_Request] = collections.deque()
        while self._queue:
            r = self._queue.popleft()
            if r.key == key and len(group) < self.max_batch:
                group.append(r)
            else:
                kept.append(r)
        self._queue.extend(kept)

    def _dispatch_loop(self) -> None:
        while True:
            with self._cv:
                while not self._queue and not self._closed:
                    self._cv.wait()
                if not self._queue:     # closed and drained
                    return
                head = self._queue.popleft()
                group = [head]
                if self.max_batch > 1:
                    deadline = time.monotonic() + self.max_wait_s
                    while len(group) < self.max_batch:
                        self._take_matching(group, head.key)
                        if len(group) >= self.max_batch or self._closed:
                            break
                        if self._queue:
                            # Everything still queued is non-matching (all
                            # matches were just taken): holding the window
                            # open would head-of-line block other keys for
                            # up to max_wait for companions that may never
                            # come. Dispatch now; stragglers form the next
                            # group.
                            break
                        remaining = deadline - time.monotonic()
                        if remaining <= 0:
                            break
                        self._cv.wait(remaining)
                    self._take_matching(group, head.key)
            self._execute_group(group)

    # ------------------------------------------- continuous (iteration-level)
    def _drain_queue_locked(self) -> None:
        """Park every queued request in its structure class's pending list."""
        while self._queue:
            req = self._queue.popleft()
            cls = self._classes.get(req.key)
            if cls is None:
                cls = self._classes[req.key] = _ClassState(req.key,
                                                           self._next_cid)
                self._next_cid += 1
            cls.pending.append(req)
            self._pending_count += 1

    def _pick_class_locked(self) -> "_ClassState | None":
        """Smooth-WRR over busy classes, weighted by their best member tier.

        A class hosting a tier-1 member gets ~2x the step slots of an
        all-tier-0 class, which is how tier priority shapes *step* order
        (admission order within a class is the per-class tier WRR).
        """
        weights: dict[tuple, int] = {}
        for key, cls in self._classes.items():
            if not cls.busy():
                continue
            w = 1
            for r in cls.resident:
                w = max(w, tier_weight(r.tenant.tier))
            for r in cls.pending:
                w = max(w, tier_weight(r.tenant.tier))
            weights[key] = w
        key = self._class_wrr.pick(weights)
        return None if key is None else self._classes[key]

    def _want_window_locked(self, cls: "_ClassState") -> bool:
        """Hold a coalescing window open for this class's first step?

        Only when the batch would otherwise start at occupancy 1 with the
        whole server idle: no residents yet, pending below max_batch,
        nothing queued, and no other class with work. A resident batch
        never waits — steps must keep their cadence for members already
        decoding — and a busy server never head-of-line blocks one class
        waiting on companions for another.
        """
        if self.max_batch <= 1 or self.max_wait_s <= 0 or self._closed:
            return False
        if cls.resident or len(cls.pending) >= self.max_batch:
            return False
        if self._queue:
            return False
        return not any(other is not cls and other.busy()
                       for other in self._classes.values())

    def _shed_expired_locked(self, cls: "_ClassState") -> list:
        """Pop members (resident or pending) whose deadline has passed."""
        now = time.monotonic()
        expired = []
        for lst in (cls.resident, cls.pending):
            for r in lst[:]:
                if r.deadline is not None and r.deadline <= now:
                    lst.remove(r)
                    if lst is cls.pending:
                        self._pending_count -= 1
                    expired.append(r)
        return expired

    def _admit_members_locked(self, cls: "_ClassState") -> int:
        """Fill free resident slots from pending, tier-weighted, FIFO in tier.

        Admission happens ONLY here — at a step boundary — so with
        ``autostart=False`` the membership of the first step is a pure
        function of what was submitted before :meth:`start`. The per-class
        :class:`SmoothWRR` picks which tier supplies each slot (weight
        ``2**tier``), and within a tier arrival order is preserved.
        """
        joins = 0
        while cls.pending and len(cls.resident) < self.max_batch:
            tiers: dict[int, int] = {}
            for r in cls.pending:
                tiers.setdefault(r.tenant.tier, 0)
                tiers[r.tenant.tier] += 1
            pick = cls.wrr.pick({t: tier_weight(t) for t in tiers})
            for i, r in enumerate(cls.pending):
                if r.tenant.tier == pick:
                    cls.resident.append(cls.pending.pop(i))
                    self._pending_count -= 1
                    joins += 1
                    break
        return joins

    def _scheduler_loop(self) -> None:
        """Continuous-batching scheduler: one fused replay step per wakeup.

        Each iteration drains the admission queue into per-class pending
        lists, picks the next class to step (tier-weighted smooth WRR),
        admits joiners / sheds expired members at the step boundary, and
        runs ONE step for that class's resident batch outside the lock.
        Members with ``steps_done < steps`` stay resident with outputs
        carried into same-named input slots; finished members retire
        without draining the batch.
        """
        while True:
            with self._cv:
                self._drain_queue_locked()
                cls = self._pick_class_locked()
                if cls is None:
                    if self._closed:
                        return
                    self._cv.wait()
                    continue
                if self._want_window_locked(cls):
                    deadline = time.monotonic() + self.max_wait_s
                    while True:
                        remaining = deadline - time.monotonic()
                        if remaining <= 0:
                            break
                        self._cv.wait(remaining)
                        self._drain_queue_locked()
                        if not self._want_window_locked(cls):
                            break
                expired = self._shed_expired_locked(cls)
                joins = self._admit_members_locked(cls)
                group = list(cls.resident)
                cls.step += 1
                step_idx = cls.step
            if expired:
                now = time.monotonic()
                self.metrics.on_deadline_shed(len(expired))
                for r in expired:
                    self.metrics.on_done(now - r.t_submit, failed=True)
                    r.future.set_exception(DeadlineExceeded(
                        f"deadline passed while queued for tenant "
                        f"{r.tenant.name!r}"))
            if group:
                self._execute_step(cls, group, step_idx,
                                   joins=joins, sheds=len(expired))

    def _execute_step(self, cls: "_ClassState", group: list, step_idx: int,
                      joins: int, sheds: int) -> None:
        """Run ONE fused replay step for a resident batch; settle membership.

        Reuses the request-level execution paths unchanged —
        ``_run_single`` for a lone resident, ``_run_batched`` (pooled
        occupancy-bucketed vmap executables, per-request serial fallback) for
        more — so membership churn hits the same intern/pool caches and
        never retraces. Afterwards: failures and finished members retire;
        survivors carry outputs into same-named input slots, and a member
        whose buffer signature drifted (shape change) migrates to the
        class that now matches instead of poisoning this batch's bucket.
        """
        t0 = time.monotonic()
        coalesced = False
        try:
            if len(group) == 1:
                results: list = [self._run_single(group[0])]
            else:
                results, coalesced = self._run_batched(group)
            jax.block_until_ready([r for r in results
                                   if not isinstance(r, Exception)])
        except Exception as exc:
            results = [exc] * len(group)
        wall_ms = (time.monotonic() - t0) * 1e3
        done: list = []
        failed: list = []
        leaves = 0
        with self._cv:
            for member, out in zip(group, results):
                if isinstance(out, Exception):
                    cls.resident.remove(member)
                    failed.append((member, out))
                    leaves += 1
                    continue
                member.steps_done += 1
                if member.steps_done >= member.steps:
                    cls.resident.remove(member)
                    done.append((member, out))
                    leaves += 1
                    continue
                tenant = member.tenant
                member.buffers = {**member.buffers,
                                  **{k: v for k, v in out.items()
                                     if k in member.buffers}}
                canon = {tenant.slot_map[k]: v
                         for k, v in member.buffers.items()
                         if k in tenant.slot_map}
                member.canon_buffers = canon
                new_key = (tenant.sig, tenant.payload_ids,
                           buffers_signature(canon), tenant.kernel_mode)
                if new_key != cls.key:
                    cls.resident.remove(member)
                    member.key = new_key
                    target = self._classes.get(new_key)
                    if target is None:
                        target = self._classes[new_key] = _ClassState(
                            new_key, self._next_cid)
                        self._next_cid += 1
                    target.pending.append(member)
                    self._pending_count += 1
                    leaves += 1
            self._cv.notify_all()
        now = time.monotonic()
        for member, exc in failed:
            self.metrics.on_done(now - member.t_submit, failed=True)
            member.future.set_exception(exc)
        for member, out in done:
            self.metrics.on_done(now - member.t_submit,
                                 aot=member.served_aot,
                                 tier=member.tenant.tier)
            member.future.set_result(out)
        self.metrics.on_batch(len(group), coalesced=coalesced)
        tiers: dict[str, int] = {}
        for member in group:
            label = str(member.tenant.tier)
            tiers[label] = tiers.get(label, 0) + 1
        # The tuner's ladder (already retuned by this step's own
        # observation, if it was going to) names the bucket the coalesced
        # path actually ran; pad lanes only exist when ONE fused call
        # served the step — the serial fallback runs nothing idle.
        bucket, padded = (1, 0) if len(group) < 2 \
            else self._bucket_and_pad(len(group))
        self.metrics.on_step({
            "step": step_idx,
            "class_id": cls.cid,
            "occupancy": len(group),
            "bucket": bucket,
            "joins": joins,
            "leaves": leaves,
            "sheds": sheds,
            "wall_ms": wall_ms,
            "coalesced": coalesced,
            "padded": padded if coalesced else 0,
            "tiers": tiers,
        })

    # ------------------------------------------------------------- execution
    def _execute_group(self, group: list[_Request]) -> None:
        # Shed members whose deadline already passed BEFORE spending a
        # replay on them: the submitter stopped waiting, so the only thing
        # executing buys is wasted compute in front of live requests.
        now = time.monotonic()
        expired = [r for r in group if r.deadline is not None
                   and r.deadline <= now]
        if expired:
            group = [r for r in group if r not in expired]
            self.metrics.on_deadline_shed(len(expired))
            for r in expired:
                self.metrics.on_done(now - r.t_submit, failed=True)
                r.future.set_exception(DeadlineExceeded(
                    f"deadline passed while queued for tenant "
                    f"{r.tenant.name!r}"))
            if not group:
                return
        coalesced = False
        try:
            if len(group) == 1:
                # A lone request (no coalescing partner inside the window)
                # takes the interned single-request path — never a K=1
                # specialization of the batched program.
                results = [self._run_single(group[0])]
            else:
                results, coalesced = self._run_batched(group)
            jax.block_until_ready(results)
        except Exception as exc:
            now = time.monotonic()
            for r in group:
                self.metrics.on_done(now - r.t_submit, failed=True)
                r.future.set_exception(exc)
            return
        self.metrics.on_batch(len(group), coalesced=coalesced)
        now = time.monotonic()
        for r, out in zip(group, results):
            if isinstance(out, Exception):      # per-request fallback failure
                self.metrics.on_done(now - r.t_submit, failed=True)
                r.future.set_exception(out)
            else:
                self.metrics.on_done(now - r.t_submit, aot=r.served_aot)
                r.future.set_result(out)

    def _run_single(self, req: _Request) -> dict:
        tenant = req.tenant
        aot = self._aot_for(req)
        if aot is not None:
            req.served_aot = True
            with _kreg.kernel_mode_scope(tenant.kernel_mode):
                return aot(req.buffers)
        fn = tenant.replay_fn()
        with _kreg.kernel_mode_scope(tenant.kernel_mode):
            return fn(dict(req.buffers))

    def _aot_for(self, req: _Request) -> "_lower.AotExecutable | None":
        """The tenant's warm AOT executable, iff shapes match this request.

        Pool-evicted AOT entries are re-hydrated from the tenant's
        ``warm_path`` sidecar when possible (cold tenants pay a disk read,
        not a retrace); irrecoverable sidecars permanently fall back to the
        interned lazy path.
        """
        tenant = req.tenant
        if tenant.aot_key is None:
            return None
        want = buffers_signature(
            {k: v for k, v in req.buffers.items()
             if k in self._aot_spec_slots(tenant)})
        if want != tenant.aot_sig:
            return None
        entry = self.pool.get(tenant.aot_key)
        if entry is not None:
            return entry.fn
        if tenant.warm_path is not None:
            try:
                aot = _serialize.load_executable(str(tenant.warm_path) + ".aot",
                                                 mesh=self.mesh_fp)
            except Exception:
                tenant.aot_key = None       # unrecoverable: stop retrying
                self.metrics.on_aot_hydrate_failure()
                return None
            self._install_aot(tenant, aot, hydrated=True)
            return aot
        tenant.aot_key = None
        return None

    def _aot_spec_slots(self, tenant: Tenant) -> tuple:
        # aot_sig rows are (slot, treedef, leafspec): recover the slot set.
        return tuple(row[0] for row in (tenant.aot_sig or ()))

    def _run_batched(self, group: list[_Request]) -> tuple[list, bool]:
        """Serve a coalesced group; returns ``(results, coalesced)``.

        ``coalesced`` is True only when ONE fused vmap-batched call served
        the whole group, so the metrics never report fallback groups as
        real cross-request fusion.
        """
        try:
            return self._run_batched_fused(group), True
        except Exception:
            # A payload without a batching rule (or any trace-time failure
            # specific to the vmapped form) degrades THIS batch to serial
            # per-request replay; single-request bugs still surface from
            # _run_single with their real error — per request, so one
            # member's failure cannot poison its siblings' results.
            self.metrics.on_batch_fallback()
            results: list[dict | Exception] = []
            for r in group:
                try:
                    results.append(self._run_single(r))
                except Exception as exc:
                    results.append(exc)
            return results, False

    def _run_batched_fused(self, group: list[_Request]) -> list[dict]:
        tenant0 = group[0].tenant
        canon = [r.canon_buffers for r in group]
        slots = sorted(canon[0])
        shared = frozenset(
            s for s in slots
            if all(cb[s] is canon[0][s] for cb in canon[1:]))
        varying = tuple(s for s in slots if s not in shared)
        shared_bufs = {s: canon[0][s] for s in shared}
        if not varying:
            # Every buffer is literally shared: one single-request replay
            # serves the whole batch (all members compute the same values).
            out0 = self._run_single(group[0])
            canon_out = {group[0].tenant.slot_map[s]: v
                         for s, v in out0.items()}
            return [{r.tenant.from_canon[c]: v for c, v in canon_out.items()}
                    for r in group]
        key = ("batched", tenant0.sig, tenant0.payload_ids, shared,
               tenant0.kernel_mode, self.mesh_fp)
        entry = self.pool.get(key)
        if entry is None:
            entry = self.pool.put(key, PoolEntry(
                "batched", self._build_batched(tenant0), tenant0.payloads))
        # Bucket occupancy (padding with a repeat of the last member,
        # dropped after the call): jit specializes the batched program per
        # pytree arity, so without bucketing every straggler-induced
        # occupancy K would pay a fresh trace+compile. Boundaries come from
        # the BucketTuner — the pow-2 ladder until the live occupancy
        # histogram justifies a refit (bounded retrace budget; static under
        # REPRO_ADAPTIVE=0). A refit retires the pool's batched entries:
        # their baked-in bucket sizes can never be requested again. Under a
        # mesh the bucket also rounds up to a batch-axis multiple so the
        # request axis always splits evenly across devices.
        per_req = [{s: cb[s] for s in varying} for cb in canon]
        if self.buckets.observe(len(per_req)):
            self.pool.invalidate(lambda k, e: e.kind == "batched")
            self.metrics.on_bucket_retune(self.buckets.boundaries)
        bucket, pad = self._bucket_and_pad(len(per_req))
        per_req.extend(per_req[-1:] * pad)
        self.metrics.on_pad(pad)
        with _kreg.kernel_mode_scope(tenant0.kernel_mode):
            outs = entry.fn(tuple(per_req), shared_bufs)
        return [{r.tenant.from_canon[c]: v for c, v in out_j.items()}
                for r, out_j in zip(group, outs)]

    def _bucket_and_pad(self, occupancy: int) -> tuple[int, int]:
        """(bucket, pad lanes) for ``occupancy`` under the current ladder.

        The tuner picks the boundary; a replay mesh then rounds up to a
        batch-axis multiple so the request axis always splits evenly.
        """
        bucket = self.buckets.bucket_for(occupancy)
        bucket += (-bucket) % _shreplay.batch_axis_size(self.mesh)
        return bucket, bucket - occupancy

    def _build_batched(self, tenant: Tenant) -> Callable[..., tuple]:
        """One jitted cross-request batch callable on canonical slot names.

        ``fn(per_request, shared) -> tuple[dict, ...]`` where
        ``per_request`` is a tuple of per-member buffer dicts. Stacking the
        request axis, ``vmap``-ing the canonical region function over it,
        and re-slicing the outputs per member ALL happen inside the one
        jitted program — a whole batch costs a single dispatch, which is
        where coalescing beats serial replay. Shared buffers enter as
        unbatched jit arguments closed over inside the vmap body, i.e.
        broadcast — the cross-request analogue of ``WaveClass.shared``
        argument handling. Occupancy is a pytree shape, so one callable
        serves every batch size via jit's per-structure specialization.
        """
        with _kreg.kernel_mode_scope(tenant.kernel_mode):
            # The inner region function stays single-device (mesh=None):
            # the request axis vmapped below is the batch dim this server
            # shards, and nesting a second wave-level shard inside it would
            # constrain axes vmap has already consumed.
            base = _lower.lower_tdg(
                tenant.tdg, jit=False, fuse=self.fuse, mesh=None,
                outputs=list(tenant.outputs)
                if tenant.outputs is not None else None)
        from_canon = tenant.from_canon
        slot_map = tenant.slot_map
        mesh = self.mesh

        def canon_base(cbufs: dict) -> dict:
            out = base({from_canon[c]: v for c, v in cbufs.items()})
            return {slot_map[s]: v for s, v in out.items()}

        def batched(per_req: tuple, shared_bufs: dict) -> tuple:
            stacked = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs, axis=0), *per_req)
            # Split the stacked request axis across the replay mesh; the
            # occupancy bucket above is always a batch-axis multiple, so
            # the constraint never degrades to replicated.
            stacked = _shreplay.shard_leading(stacked, mesh)

            def one(st: dict) -> dict:
                return canon_base({**st, **shared_bufs})

            out = jax.vmap(one)(stacked)
            return tuple(
                jax.tree_util.tree_map(lambda v, _j=j: v[_j], out)
                for j in range(len(per_req)))

        batched.__name__ = f"tdg_batched_{tenant.tdg.region}"
        return jax.jit(batched)
