"""Serving metrics: queue/batch/latency observability for the RegionServer.

Tuft et al. (arXiv:2406.03077) show that mainstream OpenMP runtimes hide
detrimental task execution patterns — work sitting in queues, dispatch
convoys, starved workers — precisely because nothing measures them. The
serving layer therefore records, per request and per batch:

* **queue depth** at admission (and its peak), so head-of-line pressure on
  the admission queue is visible rather than silent;
* **batch occupancy** — how many coalesced requests each fused replay
  actually carried vs. the configured ``max_batch`` ceiling;
* **replay latency** (submit → result) in a bounded reservoir, summarized
  as p50/p99, the standard serving SLO percentiles;
* executable-pool **hit/miss counters** (surfaced by the server from
  :class:`~repro.serving.pool.WarmPool`), the serving-level intern hit rate.

Everything here is lock-protected and cheap (O(1) per event, bounded
memory), so metrics can stay on in production serving paths.
"""
from __future__ import annotations

import math
import threading


def percentile(sorted_values: list[float], q: float) -> float:
    """Nearest-rank percentile of an already-sorted list (0 <= q <= 100).

    Classic nearest-rank: the ``ceil(q/100 * n)``-th smallest value.
    Returns 0.0 for an empty list: serving dashboards prefer a zero row
    over an exception when no traffic has arrived yet.
    """
    if not sorted_values:
        return 0.0
    if q <= 0:
        return sorted_values[0]
    if q >= 100:
        return sorted_values[-1]
    rank = math.ceil(q / 100.0 * len(sorted_values)) - 1
    return sorted_values[max(0, min(len(sorted_values) - 1, rank))]


class LatencyReservoir:
    """Bounded sample of per-request latencies (seconds).

    Keeps the most recent ``capacity`` observations (ring buffer): serving
    percentiles should reflect current behaviour, not the cold-start tail
    from an hour ago.
    """

    def __init__(self, capacity: int = 4096):
        self.capacity = max(1, capacity)
        self._buf: list[float] = []
        self._next = 0
        self.count = 0

    def record(self, seconds: float) -> None:
        if len(self._buf) < self.capacity:
            self._buf.append(seconds)
        else:
            self._buf[self._next] = seconds
            self._next = (self._next + 1) % self.capacity
        self.count += 1

    def summary(self) -> dict:
        vals = sorted(self._buf)
        return {
            "count": self.count,
            "p50_s": percentile(vals, 50),
            "p99_s": percentile(vals, 99),
            "max_s": vals[-1] if vals else 0.0,
        }


class ServerMetrics:
    """Thread-safe counters + latency reservoir for one RegionServer."""

    def __init__(self, latency_capacity: int = 4096):
        self._lock = threading.Lock()
        self.admitted = 0
        self.completed = 0
        self.failed = 0
        self.batches = 0
        self.coalesced_requests = 0   # requests served by a fused batch >= 2
        self.batch_fallbacks = 0      # batched replay failed -> serial path
        self.aot_served = 0           # requests served by a hydrated .aot
        self.aot_hydrate_failures = 0  # sidecar present but unusable -> lazy
        self.aot_topology_rejects = 0  # artifact for a different topology
        self.shed = 0                 # rejected at admission: queue bound hit
        self.deadline_sheds = 0       # dropped unexecuted: deadline expired
        self.occupancy_sum = 0
        self.occupancy_max = 0
        self.queue_depth_peak = 0
        self.queue_depth_last = 0
        self.latency = LatencyReservoir(latency_capacity)

    # -- event hooks (called by the server) --------------------------------
    def on_admit(self, queue_depth: int) -> None:
        with self._lock:
            self.admitted += 1
            self.queue_depth_last = queue_depth
            self.queue_depth_peak = max(self.queue_depth_peak, queue_depth)

    def on_admit_many(self, n: int, queue_depth: int) -> None:
        """One batch-frame admission: ``n`` requests entered the queue at
        once (the cluster wire path admits a whole frame under a single
        lock acquisition — one metrics event to match)."""
        with self._lock:
            self.admitted += n
            self.queue_depth_last = queue_depth
            self.queue_depth_peak = max(self.queue_depth_peak, queue_depth)

    def on_batch(self, occupancy: int, coalesced: bool = True) -> None:
        """Record one dispatched admission group.

        ``occupancy`` is the group size the admission queue assembled;
        ``coalesced`` says whether ONE fused (vmap-batched) replay actually
        served the group — a batch that degraded to serial per-request
        replay reports ``coalesced=False`` so ``coalesced_requests`` never
        overstates real cross-request fusion.
        """
        with self._lock:
            self.batches += 1
            self.occupancy_sum += occupancy
            self.occupancy_max = max(self.occupancy_max, occupancy)
            if coalesced and occupancy >= 2:
                self.coalesced_requests += occupancy

    def on_done(self, latency_seconds: float, failed: bool = False,
                aot: bool = False) -> None:
        with self._lock:
            if failed:
                self.failed += 1
            else:
                self.completed += 1
            if aot:
                self.aot_served += 1
            self.latency.record(latency_seconds)

    def on_batch_fallback(self) -> None:
        with self._lock:
            self.batch_fallbacks += 1

    def on_shed(self, n: int = 1) -> None:
        """``n`` requests refused at admission because the queue was at its
        configured bound — the backpressure signal. A shed request was
        never admitted, so it does not count in ``admitted``/``failed``."""
        with self._lock:
            self.shed += n

    def on_deadline_shed(self, n: int = 1) -> None:
        """``n`` admitted requests dropped *before execution* because their
        deadline had already passed — replaying them would burn compute on
        an answer nobody is waiting for. Counted in ``failed`` too (their
        futures resolve with ``DeadlineExceeded``); this counter isolates
        the deadline-driven subset."""
        with self._lock:
            self.deadline_sheds += n

    def on_aot_hydrate_failure(self) -> None:
        """A warm artifact existed but could not be hydrated.

        ``serialize.load_warm`` (and the in-band artifact path of the
        cluster tier) soft-fall back to the lazily traced replay path by
        design — but a worker that *expected* to be warm and is silently
        re-lowering is exactly the detrimental pattern the metrics exist to
        surface. Count it here so aggregated stats never report a cold
        fallback as warm.
        """
        with self._lock:
            self.aot_hydrate_failures += 1

    def on_aot_topology_reject(self) -> None:
        """A shipped artifact was compiled for a different device topology.

        Counted as a hydrate failure too (it IS one — the tenant re-lowers),
        but kept separately distinguishable: a fleet-wide topology-reject
        spike means someone is shipping artifacts across platforms or jax
        versions, which is an operator error, not a corrupt file.
        """
        with self._lock:
            self.aot_topology_rejects += 1
            self.aot_hydrate_failures += 1

    # -- reporting ---------------------------------------------------------
    def snapshot(self) -> dict:
        with self._lock:
            mean_occ = (self.occupancy_sum / self.batches
                        if self.batches else 0.0)
            return {
                "admitted": self.admitted,
                "completed": self.completed,
                "failed": self.failed,
                "batches": self.batches,
                "coalesced_requests": self.coalesced_requests,
                "batch_fallbacks": self.batch_fallbacks,
                "aot_served": self.aot_served,
                "aot_hydrate_failures": self.aot_hydrate_failures,
                "aot_topology_rejects": self.aot_topology_rejects,
                "shed": self.shed,
                "deadline_sheds": self.deadline_sheds,
                "batch_occupancy_mean": round(mean_occ, 3),
                "batch_occupancy_max": self.occupancy_max,
                "queue_depth_peak": self.queue_depth_peak,
                "queue_depth_last": self.queue_depth_last,
                "latency": self.latency.summary(),
            }
