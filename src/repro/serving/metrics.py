"""Serving metrics: queue/batch/latency observability for the RegionServer.

Tuft et al. (arXiv:2406.03077) show that mainstream OpenMP runtimes hide
detrimental task execution patterns — work sitting in queues, dispatch
convoys, starved workers — precisely because nothing measures them. The
serving layer therefore records, per request and per batch:

* **queue depth** at admission (and its peak), so head-of-line pressure on
  the admission queue is visible rather than silent;
* **batch occupancy** — how many coalesced requests each fused replay
  actually carried vs. the configured ``max_batch`` ceiling;
* **replay latency** (submit → result) in a bounded reservoir, summarized
  as p50/p99, the standard serving SLO percentiles;
* executable-pool **hit/miss counters** (surfaced by the server from
  :class:`~repro.serving.pool.WarmPool`), the serving-level intern hit rate.

Aggregate counters are necessary but not sufficient: 2406.03077's central
observation is that stragglers and occupancy collapse hide *inside* the
aggregates. The continuous-batching scheduler therefore also records one
:class:`ExecutionTraceRing` entry **per executed step** — step index,
structure class, occupancy, bucket, join/leave/shed events, per-tier
membership, wall time, and a straggler flag (wall time > 3x the class's
EMA) — dumpable as JSON (:meth:`ExecutionTraceRing.dump`) for offline
analysis, plus a per-*tier* latency reservoir so p50/p99 are visible per
QoS tier, not just fleet-wide.

Everything here is lock-protected and cheap (O(1) per event, bounded
memory), so metrics can stay on in production serving paths.
"""
from __future__ import annotations

import json
import math
import threading
import time


def percentile(sorted_values: list[float], q: float) -> float:
    """Nearest-rank percentile of an already-sorted list (0 <= q <= 100).

    Classic nearest-rank: the ``ceil(q/100 * n)``-th smallest value.
    Returns 0.0 for an empty list: serving dashboards prefer a zero row
    over an exception when no traffic has arrived yet.
    """
    if not sorted_values:
        return 0.0
    if q <= 0:
        return sorted_values[0]
    if q >= 100:
        return sorted_values[-1]
    rank = math.ceil(q / 100.0 * len(sorted_values)) - 1
    return sorted_values[max(0, min(len(sorted_values) - 1, rank))]


class LatencyReservoir:
    """Bounded sample of per-request latencies (seconds).

    Keeps the most recent ``capacity`` observations (ring buffer): serving
    percentiles should reflect current behaviour, not the cold-start tail
    from an hour ago.
    """

    def __init__(self, capacity: int = 4096):
        self.capacity = max(1, capacity)
        self._buf: list[float] = []
        self._next = 0
        self.count = 0

    def record(self, seconds: float) -> None:
        if len(self._buf) < self.capacity:
            self._buf.append(seconds)
        else:
            self._buf[self._next] = seconds
            self._next = (self._next + 1) % self.capacity
        self.count += 1

    def summary(self) -> dict:
        vals = sorted(self._buf)
        return {
            "count": self.count,
            "p50_s": percentile(vals, 50),
            "p99_s": percentile(vals, 99),
            "max_s": vals[-1] if vals else 0.0,
        }


#: The execution-pattern trace record schema: field name -> accepted types.
#: ``tiers`` maps tier (as a JSON-safe string key) -> member count at that
#: step. A record must carry exactly these fields — the benchmark gate and
#: offline tooling both call :func:`validate_trace` against this table.
TRACE_SCHEMA: dict = {
    "step": int,            # per-class step index (1-based)
    "class_id": int,        # dense id of the structure class
    "t_ms": (int, float),   # ms since the ring was created
    "occupancy": int,       # resident members this step executed
    "bucket": int,          # power-of-two occupancy bucket actually run
    "joins": int,           # members admitted at this step boundary
    "leaves": int,          # members retired/migrated at this boundary
    "sheds": int,           # members deadline-shed at this boundary
    "wall_ms": (int, float),  # step execution wall time
    "straggler": bool,      # wall_ms > 3x this class's EMA (after warmup)
    "coalesced": bool,      # one fused vmap call served the whole step
    "padded": int,          # idle pad lanes run to fill the bucket
    "tiers": dict,          # {str(tier): member count}
}


def validate_trace(records: list) -> None:
    """Raise ``ValueError`` unless every record matches :data:`TRACE_SCHEMA`.

    Exact-key validation (no missing, no extra) so schema drift between
    the scheduler and offline analysis tools fails loudly in CI rather
    than silently producing unparseable dumps.
    """
    want = set(TRACE_SCHEMA)
    for i, rec in enumerate(records):
        if not isinstance(rec, dict):
            raise ValueError(f"trace[{i}]: not a dict: {type(rec).__name__}")
        got = set(rec)
        if got != want:
            raise ValueError(
                f"trace[{i}]: fields {sorted(got)} != schema {sorted(want)}")
        for field, types in TRACE_SCHEMA.items():
            if not isinstance(rec[field], types) or (
                    types is int and isinstance(rec[field], bool)):
                raise ValueError(
                    f"trace[{i}].{field}: {type(rec[field]).__name__} is "
                    f"not {types}")
        for tier, count in rec["tiers"].items():
            if not isinstance(tier, str) or not isinstance(count, int):
                raise ValueError(f"trace[{i}].tiers: want str->int, got "
                                 f"{tier!r}: {count!r}")


class ExecutionTraceRing:
    """Bounded ring of per-step execution-pattern records.

    One entry per executed continuous-batching step (see
    :data:`TRACE_SCHEMA`). The ring computes the ``straggler`` flag itself
    from a per-class exponential moving average of step wall time — a step
    is a straggler when it takes more than ``3x`` the class's EMA, judged
    only after ``warmup`` steps so cold compiles don't flag every class's
    first step. ``capacity``-bounded like the latency reservoir: traces
    must be safe to leave on in production.
    """

    #: Steps per class before the straggler EMA is trusted.
    warmup = 5
    #: Multiplier over the class EMA that flags a straggler.
    threshold = 3.0
    #: EMA smoothing factor.
    alpha = 0.2

    def __init__(self, capacity: int = 4096):
        self.capacity = max(1, capacity)
        self._lock = threading.Lock()
        self._buf: list[dict] = []
        self._next = 0
        self._t0 = time.monotonic()
        self._ema: dict[int, tuple[float, int]] = {}   # cid -> (ema, n)
        self.count = 0
        self.stragglers = 0

    def record(self, rec: dict) -> dict:
        """Append one step record (``straggler``/``t_ms`` filled in here)."""
        rec = dict(rec)
        with self._lock:
            rec.setdefault("t_ms", (time.monotonic() - self._t0) * 1e3)
            cid, wall = rec["class_id"], float(rec["wall_ms"])
            ema, n = self._ema.get(cid, (wall, 0))
            rec["straggler"] = bool(n >= self.warmup
                                    and wall > self.threshold * ema)
            self._ema[cid] = (ema + self.alpha * (wall - ema), n + 1)
            if rec["straggler"]:
                self.stragglers += 1
            if len(self._buf) < self.capacity:
                self._buf.append(rec)
            else:
                self._buf[self._next] = rec
                self._next = (self._next + 1) % self.capacity
            self.count += 1
        return rec

    def snapshot(self) -> list[dict]:
        """The retained records, oldest first."""
        with self._lock:
            return [dict(r) for r in
                    (self._buf[self._next:] + self._buf[:self._next])]

    def summary(self) -> dict:
        with self._lock:
            return {"steps": self.count, "retained": len(self._buf),
                    "stragglers": self.stragglers,
                    "classes": len(self._ema)}

    def dump(self, path: str, meta: dict | None = None) -> dict:
        """Write the trace as JSON for offline execution-pattern analysis."""
        records = self.snapshot()
        validate_trace(records)
        doc = {"schema": sorted(TRACE_SCHEMA), **(meta or {}),
               "summary": self.summary(), "records": records}
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
        return doc


class ServerMetrics:
    """Thread-safe counters + latency reservoir for one RegionServer."""

    def __init__(self, latency_capacity: int = 4096):
        self._lock = threading.Lock()
        self.admitted = 0
        self.completed = 0
        self.failed = 0
        self.batches = 0
        self.coalesced_requests = 0   # requests served by a fused batch >= 2
        self.batch_fallbacks = 0      # batched replay failed -> serial path
        self.aot_served = 0           # requests served by a hydrated .aot
        self.aot_hydrate_failures = 0  # sidecar present but unusable -> lazy
        self.aot_topology_rejects = 0  # artifact for a different topology
        self.shed = 0                 # rejected at admission: queue bound hit
        self.deadline_sheds = 0       # dropped unexecuted: deadline expired
        self.rate_limited = 0         # refused at admission: token bucket dry
        self.joins = 0                # members admitted into resident batches
        self.leaves = 0               # members retired from resident batches
        self.pad_lanes = 0            # idle lanes run to round batches up
        self.padded_batches = 0       # batches that carried >= 1 pad lane
        self.bucket_retunes = 0       # adaptive bucket-boundary refits
        self.occupancy_sum = 0
        self.occupancy_max = 0
        self.queue_depth_peak = 0
        self.queue_depth_last = 0
        self.latency = LatencyReservoir(latency_capacity)
        self.tier_latency: dict[int, LatencyReservoir] = {}
        self._tier_capacity = latency_capacity
        self.trace = ExecutionTraceRing()

    # -- event hooks (called by the server) --------------------------------
    def on_admit(self, queue_depth: int) -> None:
        with self._lock:
            self.admitted += 1
            self.queue_depth_last = queue_depth
            self.queue_depth_peak = max(self.queue_depth_peak, queue_depth)

    def on_admit_many(self, n: int, queue_depth: int) -> None:
        """One batch-frame admission: ``n`` requests entered the queue at
        once (the cluster wire path admits a whole frame under a single
        lock acquisition — one metrics event to match)."""
        with self._lock:
            self.admitted += n
            self.queue_depth_last = queue_depth
            self.queue_depth_peak = max(self.queue_depth_peak, queue_depth)

    def on_batch(self, occupancy: int, coalesced: bool = True) -> None:
        """Record one dispatched admission group.

        ``occupancy`` is the group size the admission queue assembled;
        ``coalesced`` says whether ONE fused (vmap-batched) replay actually
        served the group — a batch that degraded to serial per-request
        replay reports ``coalesced=False`` so ``coalesced_requests`` never
        overstates real cross-request fusion.
        """
        with self._lock:
            self.batches += 1
            self.occupancy_sum += occupancy
            self.occupancy_max = max(self.occupancy_max, occupancy)
            if coalesced and occupancy >= 2:
                self.coalesced_requests += occupancy

    def on_done(self, latency_seconds: float, failed: bool = False,
                aot: bool = False, tier: int | None = None) -> None:
        with self._lock:
            if failed:
                self.failed += 1
            else:
                self.completed += 1
            if aot:
                self.aot_served += 1
            self.latency.record(latency_seconds)
            if tier is not None and not failed:
                res = self.tier_latency.get(tier)
                if res is None:
                    res = self.tier_latency[tier] = \
                        LatencyReservoir(self._tier_capacity)
                res.record(latency_seconds)

    def on_pad(self, pad_lanes: int) -> None:
        """One batched replay ran ``pad_lanes`` idle lanes to fill its
        occupancy bucket (pad members repeat the last real request and are
        never read back). Bucket granularity trades retraces for exactly
        this waste — the counter is what the adaptive tuner's drift check
        (and operators) watch to see whether the trade is still paying."""
        if pad_lanes <= 0:
            return
        with self._lock:
            self.pad_lanes += pad_lanes
            self.padded_batches += 1

    def on_bucket_retune(self, boundaries: list | None = None) -> None:
        """The bucket tuner refit its occupancy-bucket boundaries (stale
        pooled batched executables were invalidated alongside)."""
        with self._lock:
            self.bucket_retunes += 1

    def on_rate_limited(self, n: int = 1) -> None:
        """``n`` requests refused at admission because the tenant's token
        bucket was dry — per-tenant fairness, distinct from the global
        queue-bound ``shed``. Never admitted, so not in ``admitted``."""
        with self._lock:
            self.rate_limited += n

    def on_step(self, rec: dict) -> None:
        """One continuous-batching step executed: trace it + roll up the
        join/leave counters the trace would otherwise hide in a ring."""
        with self._lock:
            self.joins += rec.get("joins", 0)
            self.leaves += rec.get("leaves", 0)
        self.trace.record(rec)

    def on_batch_fallback(self) -> None:
        with self._lock:
            self.batch_fallbacks += 1

    def on_shed(self, n: int = 1) -> None:
        """``n`` requests refused at admission because the queue was at its
        configured bound — the backpressure signal. A shed request was
        never admitted, so it does not count in ``admitted``/``failed``."""
        with self._lock:
            self.shed += n

    def on_deadline_shed(self, n: int = 1) -> None:
        """``n`` admitted requests dropped *before execution* because their
        deadline had already passed — replaying them would burn compute on
        an answer nobody is waiting for. Counted in ``failed`` too (their
        futures resolve with ``DeadlineExceeded``); this counter isolates
        the deadline-driven subset."""
        with self._lock:
            self.deadline_sheds += n

    def on_aot_hydrate_failure(self) -> None:
        """A warm artifact existed but could not be hydrated.

        ``serialize.load_warm`` (and the in-band artifact path of the
        cluster tier) soft-fall back to the lazily traced replay path by
        design — but a worker that *expected* to be warm and is silently
        re-lowering is exactly the detrimental pattern the metrics exist to
        surface. Count it here so aggregated stats never report a cold
        fallback as warm.
        """
        with self._lock:
            self.aot_hydrate_failures += 1

    def on_aot_topology_reject(self) -> None:
        """A shipped artifact was compiled for a different device topology.

        Counted as a hydrate failure too (it IS one — the tenant re-lowers),
        but kept separately distinguishable: a fleet-wide topology-reject
        spike means someone is shipping artifacts across platforms or jax
        versions, which is an operator error, not a corrupt file.
        """
        with self._lock:
            self.aot_topology_rejects += 1
            self.aot_hydrate_failures += 1

    # -- reporting ---------------------------------------------------------
    def snapshot(self) -> dict:
        with self._lock:
            mean_occ = (self.occupancy_sum / self.batches
                        if self.batches else 0.0)
            return {
                "admitted": self.admitted,
                "completed": self.completed,
                "failed": self.failed,
                "batches": self.batches,
                "coalesced_requests": self.coalesced_requests,
                "batch_fallbacks": self.batch_fallbacks,
                "aot_served": self.aot_served,
                "aot_hydrate_failures": self.aot_hydrate_failures,
                "aot_topology_rejects": self.aot_topology_rejects,
                "shed": self.shed,
                "deadline_sheds": self.deadline_sheds,
                "rate_limited": self.rate_limited,
                "joins": self.joins,
                "leaves": self.leaves,
                "pad_lanes": self.pad_lanes,
                "padded_batches": self.padded_batches,
                "pad_fraction": round(
                    self.pad_lanes / (self.pad_lanes + self.occupancy_sum), 4)
                if self.pad_lanes + self.occupancy_sum else 0.0,
                "bucket_retunes": self.bucket_retunes,
                "batch_occupancy_mean": round(mean_occ, 3),
                "batch_occupancy_max": self.occupancy_max,
                "queue_depth_peak": self.queue_depth_peak,
                "queue_depth_last": self.queue_depth_last,
                "latency": self.latency.summary(),
                "tiers": {str(t): r.summary()
                          for t, r in sorted(self.tier_latency.items())},
                "trace": self.trace.summary(),
            }
