"""Deterministic, shardable data pipeline.

Design constraints from the 1000+-node target:
  * **Deterministic addressing** — batch ``i`` of host ``h`` is a pure
    function of (seed, step, host), so restart-after-failure resumes at the
    exact batch without coordination or a data server (the same principle as
    the TDG: resolve scheduling once, replay forever).
  * **Per-host sharding** — each host materializes only its slice
    (``host_batch = global_batch / num_hosts``).
  * **Packing** — documents of random length are packed into fixed
    (batch, seq_len) token grids with EOS separators and a loss mask.

Synthetic corpora stand in for real tokenized data (this container is
offline); the interface (``__getitem__(step) -> batch dict``) is what a real
tokenized-shard reader would implement.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0
    eos_id: int = 1
    pad_id: int = 0
    mean_doc_len: int = 256

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts


class SyntheticLM:
    """Markov-ish synthetic token stream: deterministic per (seed, step, host).

    Tokens follow ``t[i+1] = (a * t[i] + b + noise) % vocab`` per document —
    enough structure that a real model's loss visibly falls, which the
    end-to-end example uses as its convergence check.
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def _doc(self, rng: np.random.Generator, length: int) -> np.ndarray:
        v = self.cfg.vocab_size
        a = int(rng.integers(2, 8))
        b = int(rng.integers(1, v - 1))
        t0 = int(rng.integers(2, v))
        toks = np.empty(length, np.int64)
        toks[0] = t0
        for i in range(1, length):
            noise = int(rng.integers(0, 3))
            toks[i] = (a * toks[i - 1] + b + noise) % (v - 2) + 2
        return toks

    def batch(self, step: int) -> dict:
        c = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([c.seed, step, c.host_id]))
        docs = []
        total = 0
        need = c.host_batch * c.seq_len
        while total < need:
            ln = max(8, int(rng.exponential(c.mean_doc_len)))
            docs.append(self._doc(rng, ln))
            total += ln + 1
        tokens, mask = pack_documents(docs, c.host_batch, c.seq_len,
                                      eos_id=c.eos_id, pad_id=c.pad_id)
        return {"tokens": tokens.astype(np.int32),
                "loss_mask": mask.astype(np.float32)}

    def __getitem__(self, step: int) -> dict:
        return self.batch(step)


class MixtureDataset:
    """Weighted mixture over component datasets, deterministic per step."""

    def __init__(self, components: Sequence, weights: Sequence[float],
                 seed: int = 0):
        assert len(components) == len(weights) and components
        w = np.asarray(weights, np.float64)
        self.p = w / w.sum()
        self.components = list(components)
        self.seed = seed

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, step]))
        idx = int(rng.choice(len(self.components), p=self.p))
        return self.components[idx].batch(step)

    __getitem__ = batch


def pack_documents(docs: Sequence[np.ndarray], batch: int, seq_len: int,
                   eos_id: int = 1, pad_id: int = 0):
    """Greedy sequential packing into (batch, seq_len) with EOS separators.
    Returns (tokens, loss_mask); pad positions get mask 0."""
    flat = []
    for d in docs:
        flat.extend(int(x) for x in d)
        flat.append(eos_id)
    need = batch * seq_len
    if len(flat) < need:
        flat.extend([pad_id] * (need - len(flat)))
    arr = np.asarray(flat[:need], np.int64).reshape(batch, seq_len)
    mask = (arr != pad_id).astype(np.float32)
    return arr, mask


def make_loader(cfg: DataConfig, start_step: int = 0) -> Iterator[dict]:
    """Infinite iterator of host-local batches starting at ``start_step``
    (checkpoint-restart passes the restored step — no state to save)."""
    ds = SyntheticLM(cfg)
    step = start_step
    while True:
        yield ds.batch(step)
        step += 1
