"""Data pipeline: deterministic synthetic corpora, packing, sharded loaders."""
from .pipeline import (DataConfig, SyntheticLM, MixtureDataset, pack_documents,
                       make_loader)

__all__ = ["DataConfig", "SyntheticLM", "MixtureDataset", "pack_documents",
           "make_loader"]
