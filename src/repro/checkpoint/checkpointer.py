"""Sharded checkpointing with async save and integrity manifest.

Layout (one directory per step, one .npz per host — at 1000+ nodes each
host writes only its own param shards, no cross-host traffic):

    <dir>/step_000100/
        manifest.json       # tree structure, shapes, dtypes, host count, crc
        host_00000.npz      # flattened leaves (this host's shard slice)
        _COMMITTED          # written last: torn checkpoints are never loaded

Restart: ``latest_step`` scans for the newest COMMITTED step; loads map
leaves back through the manifest and re-shard onto the current mesh (device
count may differ — elastic restart reshards via ``jax.device_put``).
"""
from __future__ import annotations

import json
import pathlib
import threading
import time
import zlib
from typing import Any

import jax
import numpy as np


def _flatten_with_names(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out.append((name, leaf))
    return out


def save_pytree(tree, directory: str | pathlib.Path, step: int,
                host_id: int = 0, num_hosts: int = 1) -> pathlib.Path:
    d = pathlib.Path(directory) / f"step_{step:06d}"
    d.mkdir(parents=True, exist_ok=True)
    named = _flatten_with_names(tree)
    arrays = {}
    manifest = {"step": step, "num_hosts": num_hosts, "leaves": {}}
    for name, leaf in named:
        arr = np.asarray(leaf)
        arrays[name] = arr
        manifest["leaves"][name] = {
            "shape": list(arr.shape), "dtype": str(arr.dtype),
            "crc32": int(zlib.crc32(arr.tobytes())),
        }
    np.savez(d / f"host_{host_id:05d}.npz",
             **{k.replace("/", "__"): v for k, v in arrays.items()})
    if host_id == 0:
        (d / "manifest.json").write_text(json.dumps(manifest, indent=1))
    (d / "_COMMITTED").write_text(str(time.time()))
    return d


def load_pytree(template, directory: str | pathlib.Path, step: int,
                host_id: int = 0, verify: bool = True):
    d = pathlib.Path(directory) / f"step_{step:06d}"
    if not (d / "_COMMITTED").exists():
        raise FileNotFoundError(f"checkpoint {d} not committed")
    manifest = json.loads((d / "manifest.json").read_text())
    data = np.load(d / f"host_{host_id:05d}.npz")
    named = _flatten_with_names(template)
    leaves = []
    for name, tmpl in named:
        key = name.replace("/", "__")
        arr = data[key]
        meta = manifest["leaves"][name]
        if verify and int(zlib.crc32(arr.tobytes())) != meta["crc32"]:
            raise IOError(f"checksum mismatch for {name} in {d}")
        want = tuple(getattr(tmpl, "shape", arr.shape))
        if tuple(arr.shape) != want:
            raise ValueError(f"{name}: shape {arr.shape} != template {want}")
        sharding = getattr(tmpl, "sharding", None)
        leaves.append(jax.device_put(arr, sharding) if sharding is not None
                      else arr)
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def latest_step(directory: str | pathlib.Path) -> int | None:
    d = pathlib.Path(directory)
    if not d.exists():
        return None
    steps = []
    for p in d.glob("step_*"):
        if (p / "_COMMITTED").exists():
            try:
                steps.append(int(p.name.split("_")[1]))
            except ValueError:
                continue
    return max(steps) if steps else None


class Checkpointer:
    """Async checkpointer: snapshot to host memory synchronously (cheap),
    write to disk on a background thread (training never blocks on IO).
    Keeps the last ``keep`` checkpoints."""

    def __init__(self, directory: str | pathlib.Path, keep: int = 3,
                 host_id: int = 0, num_hosts: int = 1):
        self.dir = pathlib.Path(directory)
        self.keep = keep
        self.host_id = host_id
        self.num_hosts = num_hosts
        self._thread: threading.Thread | None = None
        self.saves = 0

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, tree, step: int, blocking: bool = False):
        self.wait()
        snapshot = jax.tree_util.tree_map(np.asarray, tree)  # device->host

        def _write():
            save_pytree(snapshot, self.dir, step, self.host_id,
                        self.num_hosts)
            self._gc()

        self.saves += 1
        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def restore(self, template, step: int | None = None):
        self.wait()
        step = latest_step(self.dir) if step is None else step
        if step is None:
            return None, None
        return load_pytree(template, self.dir, step, self.host_id), step

    def _gc(self):
        steps = sorted(
            int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
            if (p / "_COMMITTED").exists())
        for s in steps[:-self.keep]:
            target = self.dir / f"step_{s:06d}"
            for f in target.glob("*"):
                f.unlink()
            target.rmdir()
